"""State-space / linear-recurrent mixers: Mamba-1 (falcon-mamba) and RG-LRU
(recurrentgemma).

Both use a diagonal linear recurrence h_t = a_t ⊙ h_{t−1} + b_t, evaluated
with ``jax.lax.associative_scan`` over the sequence in training/prefill
(work-efficient parallel scan — the TPU-friendly formulation) and a single
fused step in decode. Causal depthwise conv keeps a (d_conv−1)-tap state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .param import param

# ---------------------------------------------------------------------------
# shared: diagonal linear recurrence + causal depthwise conv
# ---------------------------------------------------------------------------


def linear_recurrence(a, b, h0=None):
    """h_t = a_t ⊙ h_{t−1} + b_t along axis 1 (seq). a,b: (B,S,...)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def causal_conv_specs(width: int, channels: int):
    return {
        "w": param((width, channels), ("state", "ffn")),
        "b": param((channels,), ("ffn",), init="zeros"),
    }


def causal_conv_seq(p, x, state=None):
    """x (B,S,C); state (B,W−1,C) carried taps. Returns (y, new_state)."""
    W = p["w"].shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+W−1, C)
    y = sum(xp[:, i:i + x.shape[1]] * p["w"][i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return y + p["b"], new_state


def causal_conv_step(p, x_t, state):
    """x_t (B,1,C); state (B,W−1,C)."""
    W = p["w"].shape[0]
    taps = jnp.concatenate([state, x_t], axis=1)    # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", taps, p["w"]) + p["b"]
    return y[:, None], taps[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b): d_inner = 2·d_model, state N, dt_rank = D/16
# ---------------------------------------------------------------------------


def mamba_specs(cfg: ArchConfig):
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return {
        "in_proj": param((D, 2 * Di), ("embed", "ffn")),
        "conv": causal_conv_specs(cfg.d_conv, Di),
        "x_proj": param((Di, R + 2 * N), ("ffn", "state")),
        "dt_proj": param((R, Di), ("state", "ffn")),
        "dt_bias": param((Di,), ("ffn",), init="zeros"),
        "A_log": param((Di, N), ("ffn", "state"), init="ones",
                       dtype=jnp.float32),
        "D": param((Di,), ("ffn",), init="ones", dtype=jnp.float32),
        "out_proj": param((Di, D), ("ffn", "embed")),
    }


def _mamba_core(cfg, p, xc):
    """Shared projections: xc (B,S,Di) post-conv. Returns (dt, A, Bm, Cm)."""
    N, R = cfg.ssm_state, cfg.dt_rank
    xdb = jnp.einsum("bsc,cr->bsr", xc, p["x_proj"])
    dt, Bm, Cm = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))          # (B,S,Di)
    A = -jnp.exp(p["A_log"])                         # (Di,N)
    return dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_seq(cfg: ArchConfig, p, x, *, conv_state=None, h0=None):
    """Returns (y, (conv_state, h_last))."""
    xz = jnp.einsum("bsd,dc->bsc", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv_seq(p["conv"], xin, conv_state)
    xc = jax.nn.silu(xc)
    dt, A, Bm, Cm = _mamba_core(cfg, p, xc)
    decay = jnp.exp(dt[..., None] * A)               # (B,S,Di,N)
    drive = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    h = linear_recurrence(decay, drive, h0)          # (B,S,Di,N) f32
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm) + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    return out, (conv_state, h[:, -1])


def mamba_decode(cfg: ArchConfig, p, x_t, state):
    """x_t (B,1,D); state = (conv_state (B,W−1,Di), h (B,Di,N))."""
    conv_state, h = state
    xz = jnp.einsum("bsd,dc->bsc", x_t, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv_step(p["conv"], xin, conv_state)
    xc = jax.nn.silu(xc)
    dt, A, Bm, Cm = _mamba_core(cfg, p, xc)
    decay = jnp.exp(dt[:, 0, :, None] * A)           # (B,Di,N)
    drive = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = decay * h + drive
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["D"] * xc[:, 0].astype(jnp.float32)
    y = (y.astype(x_t.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    return out, (conv_state, h)


def mamba_state_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    return (jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32))


# ---------------------------------------------------------------------------
# RG-LRU block (recurrentgemma): gated diagonal LRU + temporal conv
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def rglru_specs(cfg: ArchConfig):
    D, Di = cfg.d_model, cfg.d_inner
    return {
        "in_proj": param((D, Di), ("embed", "ffn")),
        "gate_proj": param((D, Di), ("embed", "ffn")),
        "conv": causal_conv_specs(cfg.d_conv, Di),
        "w_input_gate": param((Di, Di), ("ffn", "state")),
        "w_rec_gate": param((Di, Di), ("ffn", "state")),
        "lambda": param((Di,), ("ffn",), init="ones", dtype=jnp.float32),
        "out_proj": param((Di, D), ("ffn", "embed")),
    }


def _rglru_gates(p, xc):
    i_t = jax.nn.sigmoid(jnp.einsum("bsc,cn->bsn", xc, p["w_input_gate"])
                         .astype(jnp.float32))
    r_t = jax.nn.sigmoid(jnp.einsum("bsc,cn->bsn", xc, p["w_rec_gate"])
                         .astype(jnp.float32))
    log_a = -_RGLRU_C * r_t * jax.nn.softplus(p["lambda"])
    a = jnp.exp(log_a)
    gated_x = i_t * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    return a, b


def rglru_seq(cfg: ArchConfig, p, x, *, conv_state=None, h0=None):
    u = jnp.einsum("bsd,dc->bsc", x, p["in_proj"])
    z = jnp.einsum("bsd,dc->bsc", x, p["gate_proj"])
    xc, conv_state = causal_conv_seq(p["conv"], u, conv_state)
    a, b = _rglru_gates(p, xc)
    h = linear_recurrence(a, b, h0)                  # (B,S,Di) f32
    y = h.astype(x.dtype) * jax.nn.gelu(z)
    return jnp.einsum("bsc,cd->bsd", y, p["out_proj"]), (conv_state, h[:, -1])


def rglru_decode(cfg: ArchConfig, p, x_t, state):
    conv_state, h = state
    u = jnp.einsum("bsd,dc->bsc", x_t, p["in_proj"])
    z = jnp.einsum("bsd,dc->bsc", x_t, p["gate_proj"])
    xc, conv_state = causal_conv_step(p["conv"], u, conv_state)
    a, b = _rglru_gates(p, xc)
    h = a[:, 0] * h + b[:, 0]
    y = (h.astype(x_t.dtype) * jax.nn.gelu(z[:, 0]))[:, None]
    return jnp.einsum("bsc,cd->bsd", y, p["out_proj"]), (conv_state, h)


def rglru_state_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    return (jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            jnp.zeros((batch, cfg.d_inner), jnp.float32))
