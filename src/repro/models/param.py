"""Annotated parameter specs: shape + dtype + logical axes + init.

One tree of ``Annotated`` leaves drives all three materializations:
  * real init (seeded, for training/tests),
  * abstract init (ShapeDtypeStruct + NamedSharding, for the dry-run — no
    allocation ever happens for the full-size configs),
  * sharding specs (via sharding.partition.resolve).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import partition as ps


@dataclasses.dataclass(frozen=True)
class Annotated:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"         # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: float | None = None   # stddev; None → 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            self.shape, self.logical_axes)


def param(shape, axes, *, dtype=jnp.bfloat16, init="normal", scale=None):
    return Annotated(tuple(int(s) for s in shape), tuple(axes),
                     dtype=dtype, init=init, scale=scale)


def _is_leaf(x):
    return isinstance(x, Annotated)


def materialize(tree: Any, rng: jax.Array, *, dtype=None) -> Any:
    """Real parameter init (small/smoke configs)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_leaf)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for ann, key in zip(leaves, keys):
        dt = dtype or ann.dtype
        if ann.init == "zeros":
            out.append(jnp.zeros(ann.shape, dt))
        elif ann.init == "ones":
            out.append(jnp.ones(ann.shape, dt))
        else:
            fan_in = ann.shape[0] if ann.init == "embed" else int(
                np.prod(ann.shape[:-1]) or 1)
            std = ann.scale if ann.scale is not None else fan_in ** -0.5
            out.append((jax.random.normal(key, ann.shape, jnp.float32)
                        * std).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract(tree: Any, mesh, rules, *, fsdp: bool = True) -> Any:
    """ShapeDtypeStruct tree with resolved NamedShardings (dry-run path)."""
    info = ps.MeshInfo.from_mesh(mesh)

    def one(ann: Annotated):
        spec = ps.resolve(ann.shape, ann.logical_axes, info, rules, fsdp=fsdp)
        return jax.ShapeDtypeStruct(
            ann.shape, ann.dtype,
            sharding=jax.sharding.NamedSharding(mesh, spec))

    return jax.tree.map(one, tree, is_leaf=_is_leaf)


def specs(tree: Any, mesh, rules, *, fsdp: bool = True) -> Any:
    info = ps.MeshInfo.from_mesh(mesh)
    return jax.tree.map(
        lambda ann: ps.resolve(ann.shape, ann.logical_axes, info, rules,
                               fsdp=fsdp),
        tree, is_leaf=_is_leaf)


def nbytes(tree: Any) -> int:
    return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
               for a in jax.tree.leaves(tree, is_leaf=_is_leaf))


def count(tree: Any) -> int:
    return sum(int(np.prod(a.shape))
               for a in jax.tree.leaves(tree, is_leaf=_is_leaf))
