"""Transformer building blocks shared by all ten assigned architectures.

Sharding-neutral by construction: every op is written so the resolver's
PartitionSpecs (sharding/partition.py) determine distribution — notably GQA
uses flat-head projections plus a *static-gather* kv expansion (measured to
partition cleanly under SPMD, unlike ``jnp.repeat``), and kv projections
contract over a sharded embed dim (measured 34 % per-device FLOP reduction
vs. replicated kv compute at mesh 16×16).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .param import param

NEG_INF = -2.0e38  # large-negative fill that survives bf16


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_specs(cfg: ArchConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"scale": param((d,), ("embed",), init="ones", dtype=jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = param((d,), ("embed",), init="zeros", dtype=jnp.float32)
    return p


def apply_norm(cfg: ArchConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (full / partial — chatglm's 2d RoPE ≡ rotary over half the head dim)
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, *, theta: float,
         pct: float = 1.0) -> jax.Array:
    """x: (..., S, n, h); positions: broadcastable to (..., S)."""
    h = x.shape[-1]
    rot = int(h * pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


def learned_pos_specs(cfg: ArchConfig, max_len: int):
    return param((max_len, cfg.d_model), ("seq", "embed"), scale=0.02)


# ---------------------------------------------------------------------------
# Attention (self + cross), one implementation for train/prefill/decode
# ---------------------------------------------------------------------------


def attention_specs(cfg: ArchConfig, *, cross: bool = False):
    D, H, K, h = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": param((D, H, h), ("embed", "heads", "head_dim")),
        "wk": param((D, K, h), ("embed", "kv_heads", "head_dim")),
        "wv": param((D, K, h), ("embed", "kv_heads", "head_dim")),
        "wo": param((H, h, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = param((h,), ("head_dim",), init="ones", dtype=jnp.float32)
        p["k_norm"] = param((h,), ("head_dim",), init="ones", dtype=jnp.float32)
    return p


def _qk_normalize(p, q, k):
    def rms(x, scale):
        xf = x.astype(jnp.float32)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (y * scale).astype(x.dtype)
    return rms(q, p["q_norm"]), rms(k, p["k_norm"])


def _kv_expand(cfg: ArchConfig, k: jax.Array) -> jax.Array:
    """(B,S,K,h) → (B,S,H,h) via static gather (SPMD-clean, no repeat)."""
    if cfg.n_kv_heads == cfg.n_heads:
        return k
    kv_map = jnp.arange(cfg.n_heads, dtype=jnp.int32) // cfg.q_per_kv
    return jnp.take(k, kv_map, axis=2)


def _attn_core(cfg: ArchConfig, q, k, v, q_pos, k_pos, *,
               causal: bool, window: int) -> jax.Array:
    """q (B,Sq,H,h); k,v (B,Sk,H,h); *_pos int32 (B,Sq)/(B,Sk); k_pos<0 ⇒ empty."""
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqnh,bknh->bnqk", q, k) * scale
    if cfg.attn_softcap:
        s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
    mask = (k_pos >= 0)[:, None, None, :]
    if causal:
        rel = q_pos[:, None, :, None] - k_pos[:, None, None, :]
        mask &= rel >= 0
        if window:
            mask &= rel < window
    s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bnqk,bknh->bqnh", p, v)


def _attn_local_chunked(cfg: ArchConfig, q, k, v, positions) -> jax.Array:
    """Block-local sliding-window attention (hillclimb lever).

    Exact for window == chunk: query chunk c attends [chunk c−1 ‖ chunk c]
    with the (0 ≤ rel < window) mask, so scores shrink from (S,S) to
    (S, 2W) — an S/2W reduction in score FLOPs and bytes (gemma3 train_4k:
    4096/2048 = 2× per local layer on top of the 75 % masked waste)."""
    B, S, H, h = q.shape
    W = cfg.window
    nc = S // W
    qc = q.reshape(B, nc, W, H, h)
    kc = k.reshape(B, nc, W, H, h)
    vc = v.reshape(B, nc, W, H, h)
    pc = positions.reshape(B, nc, W)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], 1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], 1)
    p_prev = jnp.concatenate([jnp.full_like(pc[:, :1], -1), pc[:, :-1]], 1)
    kk = jnp.concatenate([k_prev, kc], 2)          # (B,nc,2W,H,h)
    vv = jnp.concatenate([v_prev, vc], 2)
    pp = jnp.concatenate([p_prev, pc], 2)          # (B,nc,2W)
    s = jnp.einsum("bcqnh,bcknh->bcnqk", qc, kk) * (cfg.head_dim ** -0.5)
    if cfg.attn_softcap:
        s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
    rel = pc[:, :, None, :, None] - pp[:, :, None, None, :]
    mask = (pp >= 0)[:, :, None, None, :] & (rel >= 0) & (rel < W)
    s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bcnqk,bcknh->bcqnh", a, vv)
    return o.reshape(B, S, H, h)


def attention_seq(cfg: ArchConfig, p, x, positions, *, kind: str = "global",
                  causal: bool = True, kv_x: jax.Array | None = None,
                  kv_positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention (training / encoder / cross)."""
    kv_in = x if kv_x is None else kv_x
    k_pos = positions if kv_positions is None else kv_positions
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", kv_in, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", kv_in, p["wv"])
    if cfg.qk_norm:
        q, k = _qk_normalize(p, q, k)
    if cfg.pos_emb == "rope" and kv_x is None:
        q = rope(q, positions, theta=cfg.rope_theta, pct=cfg.rotary_pct)
        k = rope(k, k_pos, theta=cfg.rope_theta, pct=cfg.rotary_pct)
    k, v = _kv_expand(cfg, k), _kv_expand(cfg, v)
    window = cfg.window if kind == "local" else 0
    is_causal = causal and kv_x is None
    if (kind == "local" and cfg.local_attn_chunked and window
            and kv_x is None and causal and x.shape[1] % window == 0
            and x.shape[1] > window):
        o = _attn_local_chunked(cfg, q, k, v, positions)
    elif (not is_causal and cfg.attn_q_chunk
          and x.shape[1] % cfg.attn_q_chunk == 0
          and x.shape[1] > cfg.attn_q_chunk):
        # bidirectional/cross attention over long sequences: scan over query
        # chunks so the (B,H,Sq,Sk) score buffer never materializes whole
        # (whisper's 32k-frame encoder: peak score memory ÷ Sq/chunk)
        B, S, H, h = q.shape
        n = S // cfg.attn_q_chunk
        qs = q.reshape(B, n, cfg.attn_q_chunk, H, h).swapaxes(0, 1)
        pcs = positions.reshape(B, n, cfg.attn_q_chunk).swapaxes(0, 1)

        def body(_, qc_pc):
            qc, pc = qc_pc
            return None, _attn_core(cfg, qc, k, v, pc, k_pos,
                                    causal=False, window=0)

        _, oc = jax.lax.scan(body, None, (qs, pcs))
        o = oc.swapaxes(0, 1).reshape(B, S, H, h)
    else:
        o = _attn_core(cfg, q, k, v, positions, k_pos,
                       causal=is_causal, window=window)
    return jnp.einsum("bqnh,nhd->bqd", o, p["wo"])


# -- cached (serving) path ---------------------------------------------------


def attn_cache_specs(cfg: ArchConfig, batch: int, capacity: int,
                     dtype=jnp.bfloat16):
    K, h = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": param((batch, capacity, K, h),
                   ("batch", "cache_seq", "cache_kv", "head_dim"),
                   dtype=dtype, init="zeros"),
        "v": param((batch, capacity, K, h),
                   ("batch", "cache_seq", "cache_kv", "head_dim"),
                   dtype=dtype, init="zeros"),
        "pos": param((batch, capacity), ("batch", "cache_seq"),
                     dtype=jnp.int32, init="zeros", scale=-1.0),
    }


def init_attn_cache(cfg, batch, capacity, dtype=jnp.bfloat16):
    K, h = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, capacity, K, h), dtype),
        "v": jnp.zeros((batch, capacity, K, h), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def attention_append(cfg: ArchConfig, p, x, positions, cache, *,
                     kind: str = "global", start: jax.Array | int = 0):
    """Prefill a chunk: attend to [pre-chunk cache ‖ in-chunk k/v], then
    ring-write the chunk. Concat-before-write keeps local (windowed) layers
    correct even when the chunk wraps the ring buffer — a ring ``.set`` with
    in-chunk duplicates would clobber history the early queries still need.
    Already-written cache slots have ``pos`` entries that the position mask
    excludes (pos == −1 initially, or stale positions outside the window)."""
    B, S = x.shape[:2]
    cap = cache["k"].shape[1]
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qk_norm:
        q, k = _qk_normalize(p, q, k)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, theta=cfg.rope_theta, pct=cfg.rotary_pct)
        k = rope(k, positions, theta=cfg.rope_theta, pct=cfg.rotary_pct)
    k_all = jnp.concatenate([cache["k"].astype(q.dtype), k], axis=1)
    v_all = jnp.concatenate([cache["v"].astype(q.dtype), v], axis=1)
    pos_all = jnp.concatenate([cache["pos"], positions.astype(jnp.int32)], 1)
    window = cfg.window if kind == "local" else 0
    o = _attn_core(cfg, q, _kv_expand(cfg, k_all), _kv_expand(cfg, v_all),
                   positions, pos_all, causal=True, window=window)
    y = jnp.einsum("bqnh,nhd->bqd", o, p["wo"])
    # ring-write the chunk; drop all but the last `cap` entries when the
    # chunk wraps (duplicate-slot .set order is undefined otherwise)
    if S > cap:
        k, v = k[:, -cap:], v[:, -cap:]
        kept_pos = positions[:, -cap:]
        slots = (jnp.asarray(start) + jnp.arange(S)[-cap:]) % cap
    else:
        kept_pos = positions
        slots = (jnp.asarray(start) + jnp.arange(S)) % cap
    ck = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    cp = cache["pos"].at[:, slots].set(kept_pos.astype(jnp.int32))
    return y, {"k": ck, "v": cv, "pos": cp}


def attention_decode(cfg: ArchConfig, p, x_t, pos_t, cache, *,
                     kind: str = "global",
                     cross_cache: dict | None = None):
    """One-token decode. x_t (B,1,D); pos_t (B,1) int32 current position."""
    if cross_cache is not None:  # cross-attn: cache holds encoder k/v
        q = jnp.einsum("bsd,dnh->bsnh", x_t, p["wq"])
        if cfg.qk_norm:
            scale = p["q_norm"]
            qf = q.astype(jnp.float32)
            q = (qf * jax.lax.rsqrt(jnp.mean(qf*qf, -1, keepdims=True) + 1e-6)
                 * scale).astype(q.dtype)
        o = _attn_core(cfg, q, _kv_expand(cfg, cross_cache["k"].astype(q.dtype)),
                       _kv_expand(cfg, cross_cache["v"].astype(q.dtype)),
                       pos_t, cross_cache["pos"], causal=False, window=0)
        return jnp.einsum("bqnh,nhd->bqd", o, p["wo"]), cache
    cap = cache["k"].shape[1]
    q = jnp.einsum("bsd,dnh->bsnh", x_t, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x_t, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x_t, p["wv"])
    if cfg.qk_norm:
        q, k = _qk_normalize(p, q, k)
    if cfg.pos_emb == "rope":
        q = rope(q, pos_t, theta=cfg.rope_theta, pct=cfg.rotary_pct)
        k = rope(k, pos_t, theta=cfg.rope_theta, pct=cfg.rotary_pct)
    slot = pos_t % cap                              # (B,1) ring slot
    bidx = jnp.arange(x_t.shape[0])[:, None]
    ck = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))
    cp = cache["pos"].at[bidx, slot].set(pos_t.astype(jnp.int32))
    window = cfg.window if kind == "local" else 0
    o = _attn_core(cfg, q, _kv_expand(cfg, ck.astype(q.dtype)),
                   _kv_expand(cfg, cv.astype(q.dtype)),
                   pos_t, cp, causal=True, window=window)
    y = jnp.einsum("bqnh,nhd->bqd", o, p["wo"])
    return y, {"k": ck, "v": cv, "pos": cp}


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU or plain 2-matrix)
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ArchConfig):
    D, F = cfg.d_model, cfg.d_ff
    p = {
        "w_up": param((D, F), ("embed", "ffn")),
        "w_down": param((F, D), ("ffn", "embed")),
    }
    if cfg.mlp_gated:
        p["w_gate"] = param((D, F), ("embed", "ffn"))
    return p


def apply_mlp(cfg: ArchConfig, p, x):
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.mlp_gated:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = act(g) * u
    else:
        u = act(u)
    return jnp.einsum("bsf,fd->bsd", u, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg: ArchConfig):
    p = {"tok": param((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      init="embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = param((cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"))
    return p


def embed(cfg: ArchConfig, p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(cfg: ArchConfig, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    return jnp.einsum("bsd,dv->bsv", x, w)
