"""Mixture-of-Experts with gather-only dispatch/combine.

Design (see DESIGN.md §5): routing runs inside *routing groups* aligned with
the batch sharding, so every sort/argsort is over an unsharded axis. Expert
weights shard on the expert axis when ``E % mesh_model == 0`` (moonshot:
64/16 — true EP) and fall back to per-expert tensor parallelism on the ffn
axis otherwise (grok: 8 experts, F=32768/16).

**No scatters in the differentiated path.** XLA's SPMD partitioner handles
large scatters poorly (measured: the combine scatter-add materialized
18 replicated f32 (G,N,D) buffers ≈ 29 GiB on the 314 B config). Instead we
precompute two integer index maps once per routing decision —

    slot→token  (G,E,C):  which token fills expert e's c-th slot
    token→slot  (G,N,k):  (expert, slot, live) for each token's k choices

— and express dispatch and combine as *gathers* through them. The two
gathers are each other's transpose, so a pair of ``jax.custom_vjp``s makes
the backward pass gather-only too. The only scatters left build the s32
maps themselves (K·tokens elements, non-differentiated).

Capacity-overflow tokens are dropped (GShard semantics); the router adds the
standard load-balance auxiliary loss.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core._jax_compat import get_abstract_mesh, pvary, shard_map
import numpy as np

from ..configs.base import ArchConfig
from ..sharding import partition as ps
from .param import param


def moe_specs(cfg: ArchConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": param((D, E), ("embed", "experts"), dtype=jnp.float32),
        "w_up": param((E, D, F), ("experts", "embed", "expert_ffn")),
        "w_gate": param((E, D, F), ("experts", "embed", "expert_ffn")),
        "w_down": param((E, F, D), ("experts", "expert_ffn", "embed")),
    }


def _capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.moe_topk * cfg.capacity_factor
            / cfg.n_experts) + 1
    if c > 128:
        c = -(-c // 128) * 128
    return min(c, tokens_per_group * min(cfg.moe_topk, cfg.n_experts))


# ---------------------------------------------------------------------------
# index maps (host-of-device int plumbing; built once per routing decision)
# ---------------------------------------------------------------------------


def _routing_maps(idx: jax.Array, E: int, C: int):
    """idx: (G,N,k) top-k expert choices. Returns
    slot_tok (G,E,C) s32 token filling each slot (−1 empty), and token-major
    (e_tok, rank_tok, keep_tok) each (G,N,k)."""
    G, N, k = idx.shape
    flat_e = idx.reshape(G, N * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (G,Nk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    tok = order // k
    slot_j = order % k
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left"))(sorted_e)
    ranks = jnp.arange(N * k)[None, :] - jnp.take_along_axis(
        seg_start, sorted_e, axis=-1)                          # (G,Nk)
    keep = ranks < C
    safe_rank = jnp.where(keep, ranks, 0)

    gidx = jnp.arange(G)[:, None]
    # tiny s32 scatters building the maps (not differentiated)
    slot_tok = jnp.zeros((G, E, C), jnp.int32).at[
        gidx, sorted_e, safe_rank].add(
        jnp.where(keep, tok + 1, 0)) - 1                       # −1 = empty

    # token-major views of (rank, keep): invert the sort
    inv = jnp.argsort(order, axis=-1, stable=True)             # (G,Nk)
    rank_tok = jnp.take_along_axis(safe_rank, inv, -1).reshape(G, N, k)
    keep_tok = jnp.take_along_axis(keep, inv, -1).reshape(G, N, k)
    return slot_tok, idx, rank_tok, keep_tok


# ---------------------------------------------------------------------------
# transpose-pair gathers with custom VJPs
# ---------------------------------------------------------------------------


def _g_tokens(x, slot_tok):
    """(G,N,D) → (G,E,C,D): buf[g,e,c] = x[g, slot_tok[g,e,c]] (0 if empty)."""
    gidx = jnp.arange(x.shape[0])[:, None, None]
    live = slot_tok >= 0
    safe = jnp.where(live, slot_tok, 0)
    out = x[gidx, safe]
    return jnp.where(live[..., None], out, 0)


def _g_slots(z, e_tok, rank_tok, keep_tok):
    """(G,E,C,D) → (G,N,k,D): per-token view of its k expert slots."""
    gidx = jnp.arange(z.shape[0])[:, None, None]
    out = z[gidx, e_tok, rank_tok]
    return jnp.where(keep_tok[..., None], out, 0)


@partial(jax.custom_vjp, nondiff_argnums=())
def dispatch(x, slot_tok, e_tok, rank_tok, keep_tok):
    return _g_tokens(x, slot_tok)


def _dispatch_fwd(x, slot_tok, e_tok, rank_tok, keep_tok):
    return _g_tokens(x, slot_tok), (slot_tok, e_tok, rank_tok, keep_tok)


def _dispatch_bwd(res, ct):
    slot_tok, e_tok, rank_tok, keep_tok = res
    ct_x = jnp.sum(_g_slots(ct, e_tok, rank_tok, keep_tok), axis=2)
    return ct_x, None, None, None, None


dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@partial(jax.custom_vjp, nondiff_argnums=())
def undispatch(buf, slot_tok, e_tok, rank_tok, keep_tok):
    return _g_slots(buf, e_tok, rank_tok, keep_tok)


def _undispatch_fwd(buf, slot_tok, e_tok, rank_tok, keep_tok):
    return (_g_slots(buf, e_tok, rank_tok, keep_tok),
            (slot_tok, e_tok, rank_tok, keep_tok, buf.shape))


def _undispatch_bwd(res, ct):
    slot_tok, e_tok, rank_tok, keep_tok, buf_shape = res
    # ct: (G,N,k,D) → (G,E,C,D). Each live slot maps to exactly one (n,j):
    # gather ct at (slot_tok, slot_j) — build the j map from rank equality.
    G, N, k, D = ct.shape
    ct_flat = ct.reshape(G, N * k, D)
    # flat position of (token n, choice j) is n*k + j; recover per-slot flat
    # position: token = slot_tok, j found via matching rank — precomputed as
    # a gather: rank_tok[g, n, j] == c  ⇔  slot (e,c) belongs to (n,j).
    # Build slot_flat (G,E,C) = n*k + j via a tiny s32 scatter.
    gidx = jnp.arange(G)[:, None, None]
    flatpos = (jnp.arange(N)[None, :, None] * k
               + jnp.arange(k)[None, None, :])                  # (1,N,k)
    flatpos = jnp.broadcast_to(flatpos, (G, N, k))
    E, C = slot_tok.shape[1], slot_tok.shape[2]
    slot_flat = jnp.zeros((G, E, C), jnp.int32).at[
        gidx, e_tok, rank_tok].add(
        jnp.where(keep_tok, flatpos + 1, 0)) - 1
    live = slot_flat >= 0
    safe = jnp.where(live, slot_flat, 0)
    ct_buf = ct_flat[jnp.arange(G)[:, None, None], safe]
    ct_buf = jnp.where(live[..., None], ct_buf, 0)
    return ct_buf, None, None, None, None


undispatch.defvjp(_undispatch_fwd, _undispatch_bwd)


# ---------------------------------------------------------------------------
# the MoE layer
# ---------------------------------------------------------------------------


def apply_moe(cfg: ArchConfig, p, x):
    """x: (B, S, D); routing groups = batch rows. Returns (y, aux_loss)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_topk
    C = _capacity(cfg, S)

    logits = jnp.einsum("gnd,de->gne", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,N,E)
    w, idx = jax.lax.top_k(probs, k)                           # (G,N,k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # load-balance aux loss (Switch): E · Σ_e f_e · p̄_e
    me = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = cfg.moe_aux_coef * E * jnp.sum(fe * me)

    maps = _routing_maps(jax.lax.stop_gradient(idx), E, C)
    slot_tok, e_tok, rank_tok, keep_tok = maps

    buf = dispatch(x, slot_tok, e_tok, rank_tok, keep_tok)     # (G,E,C,D)
    buf = ps.constrain(buf, [("pod", "data"), "model", None, None])

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", act(g) * u, p["w_down"])
    out = ps.constrain(out, [("pod", "data"), "model", None, None])

    o_tok = undispatch(out, slot_tok, e_tok, rank_tok, keep_tok)  # (G,N,k,D)
    y = jnp.sum(o_tok * w[..., None], axis=2)
    return y, aux


# ---------------------------------------------------------------------------
# shard_map variant (hillclimb lever, DESIGN.md §5 / EXPERIMENTS §Perf):
# expert-parallel combine as a *partial-sum + psum* instead of all-gathering
# the (G,E,C,D) expert outputs over the model axis. Per layer per microbatch
# the combine volume drops from E·C·D (gather) to N·D (psum).
# Requires E % mesh_model == 0 (true EP); otherwise falls back.
# ---------------------------------------------------------------------------


def _moe_local(cfg: ArchConfig, p, x, n_model: int):
    """Per-shard body (single-device semantics; scatters are local here)."""
    G, N, D = x.shape
    E, k = cfg.n_experts, cfg.moe_topk
    C = _capacity(cfg, N)
    e_loc = E // n_model
    my_col = jax.lax.axis_index("model")

    logits = jnp.einsum("gnd,de->gne", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # aux loss is nonlinear in (fe, me): global means must be taken BEFORE
    # the product (a per-shard aux averaged afterwards is a different loss)
    me_l = jnp.mean(probs, axis=(0, 1))
    fe_l = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    me = jax.lax.pmean(pvary(me_l, ("model",)), ("data", "model"))
    fe = jax.lax.pmean(pvary(fe_l, ("model",)), ("data", "model"))
    aux = cfg.moe_aux_coef * E * jnp.sum(fe * me)

    # keep only this shard's experts: remap to local ids, route everything
    # else to a drop bucket (expert id e_loc), then reuse the token-major
    # gather machinery (_routing_maps / dispatch / undispatch) — identical
    # autodiff structure to the validated single-device path.
    idx = jax.lax.stop_gradient(idx)
    mine = (idx // e_loc) == my_col
    local_idx = jnp.where(mine, idx - my_col * e_loc, e_loc)   # (G,N,k)
    slot_tok, e_tok, rank_tok, keep_tok = _routing_maps(
        local_idx, e_loc + 1, C)
    slot_tok = slot_tok[:, :e_loc]                # drop the overflow bucket
    keep_tok = keep_tok & (e_tok < e_loc)
    e_tok = jnp.where(e_tok < e_loc, e_tok, 0)

    # pvary: x is model-invariant but the dispatch result is model-varying;
    # marking it explicitly makes the custom-VJP cotangent types line up and
    # its transpose (psum over 'model') is exactly the right math
    xv = pvary(x, ("model",))
    buf = dispatch(xv, slot_tok, e_tok, rank_tok, keep_tok)    # (G,e_loc,C,D)

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", act(g) * u, p["w_down"])

    o_tok = undispatch(out, slot_tok, e_tok, rank_tok, keep_tok)  # (G,N,k,D)
    y_part = jnp.sum(o_tok * w[..., None], axis=2)
    y = jax.lax.psum(y_part, "model")             # N·D combine, not E·C·D
    return y, aux


def apply_moe_shardmap(cfg: ArchConfig, p, x):
    from jax.sharding import PartitionSpec as P
    mesh = get_abstract_mesh()
    axes = dict(mesh.shape)
    n_model = axes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    p_specs = {
        "router": P(None, None),
        "w_up": P("model", None, None),
        "w_gate": P("model", None, None),
        "w_down": P("model", None, None),
    }
    fn = shard_map(
        lambda p_, x_: _moe_local(cfg, p_, x_, n_model),
        mesh=mesh,
        in_specs=(p_specs, P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
    )
    return fn(p, x)


def apply_moe_auto(cfg: ArchConfig, p, x):
    """Module selection (the paper's translator idea): pick the EP-psum
    shard_map implementation when the mesh allows it, else the gather one."""
    if cfg.moe_impl == "shardmap":
        mesh = get_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            n_model = dict(mesh.shape).get("model", 1)
            if (n_model > 1 and cfg.n_experts % n_model == 0
                    and x.shape[0] % max(
                        np.prod([dict(mesh.shape).get(a, 1)
                                 for a in ("pod", "data")]), 1) == 0):
                return apply_moe_shardmap(cfg, p, x)
    return apply_moe(cfg, p, x)
