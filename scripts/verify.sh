#!/usr/bin/env bash
# Tier-1 verification: fast test suite + docs link check.
#
#   scripts/verify.sh          # tier-1 suite (slow tests excluded) + doc check
#   scripts/verify.sh --slow   # additionally run the slow suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# run the structural IR verifier between every pass pair of every
# translation below (tests set this themselves; smokes inherit it here)
export REPRO_VERIFY_IR=1

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== lint smoke: templates clean, known-bad fixture caught =="
# Shipped templates must lint clean (warnings allowed, no errors); the
# deliberately broken fixture must fail with the A003 overflow finding.
python -m repro.lint --all
if python -m repro.lint tests/fixtures/bad_program.py \
        >/tmp/lint_bad.out 2>&1; then
    echo "FAIL: lint accepted the known-bad fixture"
    cat /tmp/lint_bad.out
    exit 1
fi
grep -q "A003" /tmp/lint_bad.out
echo "lint smoke OK"

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow suite =="
    python -m pytest -q -m slow
fi

echo "== docs link check =="
# Every src/... or benchmarks/... path named in the docs must exist.
python - <<'EOF'
import pathlib, re, sys

missing = []
for doc in [pathlib.Path("docs/architecture.md"), pathlib.Path("README.md")]:
    for path in re.findall(r"`((?:src|benchmarks|scripts|docs)/[\w/.-]+\.\w+)`",
                           doc.read_text()):
        if not pathlib.Path(path).exists():
            missing.append(f"{doc}: {path}")
if missing:
    print("MISSING paths referenced by docs:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("all doc-referenced module paths exist")
EOF

echo "== perf smoke: auto-direction BFS must not lose to pull =="
# The regression PR 3 fixed: the chunk-scanned push engine made auto mode
# 0.16x (6x slower) the speed of pull on the 50k/500k R-MAT.  Candidates
# are timed *interleaved* (round-robin best-of-5, warm-up excluded):
# block timing on this shared 2-core box drifts by milliseconds and
# would land on one candidate.  The bound is 1.25x, not the pre-rebuild
# 1.05x: the flat-sweep pull rebuild narrowed the push/pull crossover to
# a wash on this graph (pull's full sweep now streams at ~1.2 ns/slot,
# about what a compacted push superstep pays in fixed machinery), so
# auto's wall clock sits within ~15-20% of pull either way and its
# durable win is the edge-traversal reduction — separately guarded
# below, and the real figure on hardware whose cost model matches the
# paper's (an FPGA/TPU frontier FIFO).  The 1.25x bound still catches
# the catastrophic-regression class this smoke exists for.
python - <<'EOF'
import time, sys
import jax
from repro.core import algorithms as alg, dsl, graph as G
from repro.core.scheduler import DirectionPolicy, ScheduleConfig
from repro.core.translator import translate

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)

progs, stats, walls = {}, {}, {}
for mode in ("pull", "auto"):
    progs[mode] = translate(dsl.bfs_program(alg.INT_MAX), g,
                            ScheduleConfig(direction=DirectionPolicy(mode=mode)))
    jax.block_until_ready(progs[mode].run(roots=0)[0])   # warm-up
    stats[mode] = progs[mode].last_run_stats
    walls[mode] = float("inf")
for _ in range(5):
    for mode, prog in progs.items():
        t0 = time.perf_counter()
        values, _ = prog.run(roots=0)
        jax.block_until_ready(values)
        walls[mode] = min(walls[mode], time.perf_counter() - t0)

speedup = walls["pull"] / walls["auto"]
reduction = stats["pull"]["edges_traversed"] / stats["auto"]["edges_traversed"]
print(f"pull {walls['pull']*1e3:.1f} ms, auto {walls['auto']*1e3:.1f} ms "
      f"-> {speedup:.2f}x; traversal reduction {reduction:.2f}x")
if walls["auto"] > walls["pull"] * 1.25:
    print("FAIL: auto-direction BFS is slower than pull (the PR-3 regression)")
    sys.exit(1)
if reduction < 3.0:
    print("FAIL: auto mode lost the edge-traversal reduction")
    sys.exit(1)
print("perf smoke OK")
EOF

echo "== perf smoke: pull plane must not lose to the dense sweep =="
# The regression the pull rebuild could introduce, guarded on two levels
# (interleaved best-of-5 BFS runs on the 50k R-MAT from a hub root —
# wide frontiers, routing overhead shows — and a low-degree root —
# narrow frontiers, skipping engages):
#   1. the SHIPPED default (pull_sweep='auto', which resolves to the
#      flat dense sweep on this XLA/CPU backend) must stay within 5% of
#      an explicit dense pin — a future auto-resolution change can't
#      silently ship a slower pull plane;
#   2. the FORCED bitmap plane must stay within its measured routing
#      cost of dense (<= 1.35x): on CPU the block-skip bookkeeping is a
#      known, documented ~10-25% tax (why 'auto' resolves dense here —
#      see BENCH_graph.json pull_plane), and this bound catches the
#      plane itself catastrophically regressing.
# Both planes are also pinned bit-exact against each other.
python - <<'EOF'
import time, sys
import numpy as np
import jax
from repro.core import algorithms as alg, dsl, graph as G
from repro.core.scheduler import DirectionPolicy, ScheduleConfig
from repro.core.translator import translate

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)
deg = np.asarray(g.out_degrees)
roots = {"hub": 0, "lowdeg": int(np.nonzero(deg == 1)[0][0])}

progs = {}
for name, sweep in (("default", "auto"), ("dense", "dense"),
                    ("bitmap", "bitmap")):
    progs[name] = translate(
        dsl.bfs_program(alg.INT_MAX), g,
        ScheduleConfig(direction=DirectionPolicy(mode="pull"),
                       pull_sweep=sweep))
assert progs["dense"].report.pull_sweep == "dense"
assert progs["bitmap"].report.pull_sweep == "bitmap"
print(f"  shipped default resolves pull_sweep="
      f"{progs['default'].report.pull_sweep}")

ok = True
for tag, root in roots.items():
    levels = {n: np.asarray(p.run(roots=root)[0])     # warm-up + levels
              for n, p in progs.items()}
    for n in ("default", "bitmap"):
        if not np.array_equal(levels[n], levels["dense"]):
            print(f"FAIL: [{tag}] {n} pull diverged from dense pull")
            ok = False
    s = progs["bitmap"].last_run_stats
    print(f"  [{tag}] bitmap blocks swept/skipped: "
          f"{s['pull_blocks_swept']}/{s['pull_blocks_skipped']}")
    walls = {n: float("inf") for n in progs}
    for _ in range(5):
        for name, prog in progs.items():
            t0 = time.perf_counter()
            vals, _ = prog.run(roots=root)
            jax.block_until_ready(vals)
            walls[name] = min(walls[name], time.perf_counter() - t0)
    for name, bound in (("default", 1.05), ("bitmap", 1.35)):
        ratio = walls[name] / walls["dense"]
        print(f"  [{tag}] {name} {walls[name]*1e3:.1f} ms vs dense "
              f"{walls['dense']*1e3:.1f} ms -> {ratio:.2f}x "
              f"(bound {bound}x)")
        if ratio > bound:
            print(f"FAIL: [{tag}] {name} pull plane is >{bound}x the "
                  "dense sweep")
            ok = False
if not ok:
    sys.exit(1)
print("pull-plane smoke OK")
EOF

echo "== multi-PE smoke: pes=2 auto BFS must stay bit-exact =="
# The sharded forward-ELL push engine: under forced host devices a pes=2
# auto BFS must (a) be bit-identical to pes=1, (b) actually run push
# supersteps across the mesh (the single-PE legality pin is gone), and
# (c) keep the direction optimization's traversal reduction.
XLA_FLAGS="--xla_force_host_platform_device_count=8" JAX_PLATFORMS=cpu \
python - <<'EOF'
import sys
import numpy as np
from repro.core import algorithms as alg, graph as G
from repro.core.comm import CommManager

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)
l1, _, _ = alg.bfs(g, root=0, pes=1, direction="auto")
_, _, rp = alg.bfs(g, root=0, pes=2, direction="pull")
comm = CommManager()
l2, _, rep = alg.bfs(g, root=0, pes=2, direction="auto", comm=comm)
s = rep.run_stats
print(f"pes={rep.pes} plane={rep.exchange_plane} "
      f"push={s['push_supersteps']} exchange={s['exchange_supersteps']} "
      f"supersteps / {s['exchange_bytes']} B "
      f"(comm total {comm.stats.collective_bytes_total} B)")
if not np.array_equal(np.asarray(l1), np.asarray(l2)):
    print("FAIL: pes=2 auto BFS diverged from pes=1")
    sys.exit(1)
if rep.pes != 2 or s["push_supersteps"] < 1:
    print("FAIL: sharded push engine did not engage (pes pin regressed?)")
    sys.exit(1)
reduction = rp.run_stats["edges_traversed"] / s["edges_traversed"]
print(f"traversal reduction vs pull @pes=2: {reduction:.2f}x")
if reduction < 3.0:
    print("FAIL: multi-PE auto lost the edge-traversal reduction")
    sys.exit(1)
print("multi-PE smoke OK")
EOF

echo "== serving smoke: mixed stream, every answer matches its oracle =="
# The serving plane (continuous-batched query runtime): a short mixed
# bfs/sssp/dist stream on a small weighted R-MAT must drain with every
# answer bit-exact against the sequential run(roots=root) oracle and a
# positive sustained QPS.  benchmarks.serve --smoke raises on any
# mismatch and asserts qps > 0; the grep pins the success line so a
# silently-empty run can't pass.
python -m benchmarks.serve --smoke | tee /tmp/serve_smoke.out
grep -q "serve smoke ok" /tmp/serve_smoke.out

echo "== partitioned smoke: out-of-core BFS bit-equal to resident =="
# The streamed execution plane: a small partition budget must force the
# 50k R-MAT through >= 3 interval partitions, the bitmap-frontier
# summary must skip at least one dead partition before transfer, and
# the streamed levels must be bit-identical to the resident path.
python - <<'EOF'
import sys
import numpy as np
from repro.core import dsl, graph as G
from repro.core.scheduler import ScheduleConfig, estimate_stream_bytes
from repro.core.translator import translate

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)

ref, _ = translate(dsl.bfs_program(), g, ScheduleConfig()).run(roots=0)
budget = estimate_stream_bytes(g.num_edges) // 4 + 1   # -> 4 partitions
prog = translate(dsl.bfs_program(), g,
                 ScheduleConfig(partition_budget_bytes=budget))
got, _ = prog.run(roots=0)
s = prog.last_run_stats
print(f"partitions={s['partitions']} swept={s['partitions_swept']} "
      f"skipped={s['partitions_skipped']} "
      f"h2d={s['partition_bytes_h2d']} B "
      f"overlap={s['overlap_efficiency']:.2f}")
if s["partitions"] < 3:
    print(f"FAIL: budget resolved to {s['partitions']} partitions (< 3)")
    sys.exit(1)
if not np.array_equal(np.asarray(ref), np.asarray(got)):
    print("FAIL: partitioned BFS diverged from the resident path")
    sys.exit(1)
if s["partitions_skipped"] < 1:
    print("FAIL: frontier summary never skipped a dead partition")
    sys.exit(1)
print("partitioned smoke ok")
EOF

echo "== fault-injection smoke: corrupted partition recovers bit-equal =="
# The fault-tolerance contract end-to-end: corrupt ONE read of one
# partition of a 3-partition container mid-stream; the per-partition
# CRC32 must catch it on fetch, the fetch path must evict + rebuild
# from the container exactly once (counters say so), and the recovered
# BFS must be bit-identical to the resident run.  Then a *persistent*
# corruption must surface as the typed ChecksumError — never a hang,
# never a silently wrong answer.
python - <<'EOF'
import sys, tempfile, os
import numpy as np
from repro import errors
from repro.core import dsl, faults, graph as G
from repro.core.comm import CommManager
from repro.core.scheduler import ScheduleConfig
from repro.core.translator import translate
from repro.data import graphs as D

src, dst = G.rmat_edges(20_000, 200_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=20_000)
ref, _ = translate(dsl.bfs_program(), g, ScheduleConfig()).run(roots=0)

with tempfile.TemporaryDirectory() as td:
    path = D.container_from_graph(os.path.join(td, "c.npz"), g, 3)
    c = D.load_partition_container(path)
    comm = CommManager()
    prog = translate(dsl.bfs_program(), c, ScheduleConfig(), comm)
    with faults.injected("container.read", mode="corrupt", times=1) as plan:
        got, _ = prog.run(roots=0)
    s = prog.last_run_stats
    print(f"injected corruptions={plan.fired} "
          f"detected+rebuilt={s['partition_corruptions']} "
          f"retries={s['partition_retries']} "
          f"terminated={s['terminated']}")
    if plan.fired != 1 or s["partition_corruptions"] != 1:
        print("FAIL: corruption not detected exactly once")
        sys.exit(1)
    if not np.array_equal(np.asarray(ref), np.asarray(got)):
        print("FAIL: recovered streamed BFS diverged from resident")
        sys.exit(1)
    prog2 = translate(dsl.bfs_program(),
                      D.load_partition_container(path),
                      ScheduleConfig(), CommManager())
    try:
        with faults.injected("container.read", mode="corrupt",
                             times=10**6):
            prog2.run(roots=0)
        print("FAIL: persistent corruption did not raise")
        sys.exit(1)
    except errors.ChecksumError as e:
        print(f"persistent corruption raised typed error: {e}")
print("fault-injection smoke ok")
EOF

echo "== crash-recovery smoke: killed streamed BFS resumes bit-equal =="
# The durability contract end-to-end: a 3-partition streamed BFS with a
# checkpoint directory is killed at a seeded superstep boundary via the
# lane.crash injection point, then a completely fresh program (new
# translate, new CommManager) resumes from the last committed snapshot.
# The resumed levels must be bit-identical to an uninterrupted run and
# run_stats must record exactly one checkpoint load.
python - <<'EOF'
import sys, tempfile, os
import numpy as np
from repro import errors
from repro.core import dsl, faults, graph as G
from repro.core.comm import CommManager
from repro.core.scheduler import ScheduleConfig
from repro.core.translator import translate
from repro.data import graphs as D

src, dst = G.rmat_edges(20_000, 200_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=20_000)
ref, ref_iters = translate(dsl.bfs_program(), g, ScheduleConfig()).run(
    roots=0)

with tempfile.TemporaryDirectory() as td:
    path = D.container_from_graph(os.path.join(td, "c.npz"), g, 3)
    ck = os.path.join(td, "ckpt")
    prog = translate(dsl.bfs_program(), D.load_partition_container(path),
                     ScheduleConfig(), CommManager(), checkpoint_dir=ck,
                     checkpoint_every=1)
    try:
        with faults.injected("lane.crash", times=1, after=4):
            prog.run(roots=0)
        print("FAIL: seeded crash never fired")
        sys.exit(1)
    except errors.InjectedFault:
        pass
    prog2 = translate(dsl.bfs_program(), D.load_partition_container(path),
                      ScheduleConfig(), CommManager(), checkpoint_dir=ck,
                      checkpoint_every=1)
    got, iters = prog2.run(roots=0, resume=True)
    s = prog2.last_run_stats
    print(f"resumed: loads={s['checkpoint_loads']} "
          f"saves={s['checkpoint_saves']} iters={int(iters)} "
          f"terminated={s['terminated']}")
    if s["checkpoint_loads"] != 1:
        print("FAIL: resume did not load exactly one checkpoint")
        sys.exit(1)
    if int(iters) != int(ref_iters) or \
            not np.array_equal(np.asarray(ref), np.asarray(got)):
        print("FAIL: resumed streamed BFS diverged from uninterrupted run")
        sys.exit(1)
print("crash-recovery smoke ok")
EOF

echo "== docstring check (core/ir.py, core/passes.py) =="
python - <<'EOF'
import inspect, sys
from repro.core import ir, passes

missing = []
for mod in (ir, passes):
    for name in mod.__all__:
        obj = getattr(mod, name)
        if not inspect.getdoc(obj):
            missing.append(f"{mod.__name__}.{name}")
        if inspect.isclass(obj):
            for m, fn in vars(obj).items():
                if callable(fn) and not m.startswith("_") \
                        and not inspect.getdoc(fn):
                    missing.append(f"{mod.__name__}.{name}.{m}")
if missing:
    print("public symbols missing docstrings:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("every public IR/pass symbol has a docstring")
EOF

echo "verify OK"
