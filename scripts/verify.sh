#!/usr/bin/env bash
# Tier-1 verification: fast test suite + docs link check.
#
#   scripts/verify.sh          # tier-1 suite (slow tests excluded) + doc check
#   scripts/verify.sh --slow   # additionally run the slow suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow suite =="
    python -m pytest -q -m slow
fi

echo "== docs link check =="
# Every src/... or benchmarks/... path named in the docs must exist.
python - <<'EOF'
import pathlib, re, sys

missing = []
for doc in [pathlib.Path("docs/architecture.md"), pathlib.Path("README.md")]:
    for path in re.findall(r"`((?:src|benchmarks|scripts|docs)/[\w/.-]+\.\w+)`",
                           doc.read_text()):
        if not pathlib.Path(path).exists():
            missing.append(f"{doc}: {path}")
if missing:
    print("MISSING paths referenced by docs:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("all doc-referenced module paths exist")
EOF

echo "== perf smoke: auto-direction BFS must not lose to pull =="
# The regression PR 3 fixed: the chunk-scanned push engine made auto mode
# 0.16x the speed of pull on the 50k/500k R-MAT.  With the compacted
# forward-ELL engine auto must at least match pull in wall time while
# keeping the ~5x edge-traversal reduction.  Best-of-3 each; 5% tolerance
# absorbs CI timer noise (the regression this guards against was 6x).
python - <<'EOF'
import time, sys
import jax
from repro.core import algorithms as alg, dsl, graph as G
from repro.core.scheduler import DirectionPolicy, ScheduleConfig
from repro.core.translator import translate

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)

def best_of(prog, n=3):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        values, _ = prog.run(roots=0)
        jax.block_until_ready(values)
        best = min(best, time.perf_counter() - t0)
    return best

walls, stats = {}, {}
for mode in ("pull", "auto"):
    prog = translate(dsl.bfs_program(alg.INT_MAX), g,
                     ScheduleConfig(direction=DirectionPolicy(mode=mode)))
    walls[mode] = best_of(prog)
    stats[mode] = prog.last_run_stats

speedup = walls["pull"] / walls["auto"]
reduction = stats["pull"]["edges_traversed"] / stats["auto"]["edges_traversed"]
print(f"pull {walls['pull']*1e3:.1f} ms, auto {walls['auto']*1e3:.1f} ms "
      f"-> {speedup:.2f}x; traversal reduction {reduction:.2f}x")
if walls["auto"] > walls["pull"] * 1.05:
    print("FAIL: auto-direction BFS is slower than pull (the PR-3 regression)")
    sys.exit(1)
if reduction < 3.0:
    print("FAIL: auto mode lost the edge-traversal reduction")
    sys.exit(1)
print("perf smoke OK")
EOF

echo "== docstring check (core/ir.py, core/passes.py) =="
python - <<'EOF'
import inspect, sys
from repro.core import ir, passes

missing = []
for mod in (ir, passes):
    for name in mod.__all__:
        obj = getattr(mod, name)
        if not inspect.getdoc(obj):
            missing.append(f"{mod.__name__}.{name}")
        if inspect.isclass(obj):
            for m, fn in vars(obj).items():
                if callable(fn) and not m.startswith("_") \
                        and not inspect.getdoc(fn):
                    missing.append(f"{mod.__name__}.{name}.{m}")
if missing:
    print("public symbols missing docstrings:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("every public IR/pass symbol has a docstring")
EOF

echo "verify OK"
