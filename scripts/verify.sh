#!/usr/bin/env bash
# Tier-1 verification: fast test suite + docs link check.
#
#   scripts/verify.sh          # tier-1 suite (slow tests excluded) + doc check
#   scripts/verify.sh --slow   # additionally run the slow suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow suite =="
    python -m pytest -q -m slow
fi

echo "== docs link check =="
# Every src/... or benchmarks/... path named in the docs must exist.
python - <<'EOF'
import pathlib, re, sys

missing = []
for doc in [pathlib.Path("docs/architecture.md"), pathlib.Path("README.md")]:
    for path in re.findall(r"`((?:src|benchmarks|scripts|docs)/[\w/.-]+\.\w+)`",
                           doc.read_text()):
        if not pathlib.Path(path).exists():
            missing.append(f"{doc}: {path}")
if missing:
    print("MISSING paths referenced by docs:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("all doc-referenced module paths exist")
EOF

echo "== perf smoke: auto-direction BFS must not lose to pull =="
# The regression PR 3 fixed: the chunk-scanned push engine made auto mode
# 0.16x the speed of pull on the 50k/500k R-MAT.  With the compacted
# forward-ELL engine auto must at least match pull in wall time while
# keeping the ~5x edge-traversal reduction.  Best-of-3 each; 5% tolerance
# absorbs CI timer noise (the regression this guards against was 6x).
python - <<'EOF'
import time, sys
import jax
from repro.core import algorithms as alg, dsl, graph as G
from repro.core.scheduler import DirectionPolicy, ScheduleConfig
from repro.core.translator import translate

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)

def best_of(prog, n=3):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        values, _ = prog.run(roots=0)
        jax.block_until_ready(values)
        best = min(best, time.perf_counter() - t0)
    return best

walls, stats = {}, {}
for mode in ("pull", "auto"):
    prog = translate(dsl.bfs_program(alg.INT_MAX), g,
                     ScheduleConfig(direction=DirectionPolicy(mode=mode)))
    walls[mode] = best_of(prog)
    stats[mode] = prog.last_run_stats

speedup = walls["pull"] / walls["auto"]
reduction = stats["pull"]["edges_traversed"] / stats["auto"]["edges_traversed"]
print(f"pull {walls['pull']*1e3:.1f} ms, auto {walls['auto']*1e3:.1f} ms "
      f"-> {speedup:.2f}x; traversal reduction {reduction:.2f}x")
if walls["auto"] > walls["pull"] * 1.05:
    print("FAIL: auto-direction BFS is slower than pull (the PR-3 regression)")
    sys.exit(1)
if reduction < 3.0:
    print("FAIL: auto mode lost the edge-traversal reduction")
    sys.exit(1)
print("perf smoke OK")
EOF

echo "== multi-PE smoke: pes=2 auto BFS must stay bit-exact =="
# The sharded forward-ELL push engine: under forced host devices a pes=2
# auto BFS must (a) be bit-identical to pes=1, (b) actually run push
# supersteps across the mesh (the single-PE legality pin is gone), and
# (c) keep the direction optimization's traversal reduction.
XLA_FLAGS="--xla_force_host_platform_device_count=8" JAX_PLATFORMS=cpu \
python - <<'EOF'
import sys
import numpy as np
from repro.core import algorithms as alg, graph as G
from repro.core.comm import CommManager

src, dst = G.rmat_edges(50_000, 500_000, seed=0)
g = G.from_edge_list(src, dst, num_vertices=50_000)
l1, _, _ = alg.bfs(g, root=0, pes=1, direction="auto")
_, _, rp = alg.bfs(g, root=0, pes=2, direction="pull")
comm = CommManager()
l2, _, rep = alg.bfs(g, root=0, pes=2, direction="auto", comm=comm)
s = rep.run_stats
print(f"pes={rep.pes} plane={rep.exchange_plane} "
      f"push={s['push_supersteps']} exchange={s['exchange_supersteps']} "
      f"supersteps / {s['exchange_bytes']} B "
      f"(comm total {comm.stats.collective_bytes_total} B)")
if not np.array_equal(np.asarray(l1), np.asarray(l2)):
    print("FAIL: pes=2 auto BFS diverged from pes=1")
    sys.exit(1)
if rep.pes != 2 or s["push_supersteps"] < 1:
    print("FAIL: sharded push engine did not engage (pes pin regressed?)")
    sys.exit(1)
reduction = rp.run_stats["edges_traversed"] / s["edges_traversed"]
print(f"traversal reduction vs pull @pes=2: {reduction:.2f}x")
if reduction < 3.0:
    print("FAIL: multi-PE auto lost the edge-traversal reduction")
    sys.exit(1)
print("multi-PE smoke OK")
EOF

echo "== docstring check (core/ir.py, core/passes.py) =="
python - <<'EOF'
import inspect, sys
from repro.core import ir, passes

missing = []
for mod in (ir, passes):
    for name in mod.__all__:
        obj = getattr(mod, name)
        if not inspect.getdoc(obj):
            missing.append(f"{mod.__name__}.{name}")
        if inspect.isclass(obj):
            for m, fn in vars(obj).items():
                if callable(fn) and not m.startswith("_") \
                        and not inspect.getdoc(fn):
                    missing.append(f"{mod.__name__}.{name}.{m}")
if missing:
    print("public symbols missing docstrings:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("every public IR/pass symbol has a docstring")
EOF

echo "verify OK"
