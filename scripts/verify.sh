#!/usr/bin/env bash
# Tier-1 verification: fast test suite + docs link check.
#
#   scripts/verify.sh          # tier-1 suite (slow tests excluded) + doc check
#   scripts/verify.sh --slow   # additionally run the slow suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow suite =="
    python -m pytest -q -m slow
fi

echo "== docs link check =="
# Every src/... or benchmarks/... path named in the docs must exist.
python - <<'EOF'
import pathlib, re, sys

missing = []
for doc in [pathlib.Path("docs/architecture.md"), pathlib.Path("README.md")]:
    for path in re.findall(r"`((?:src|benchmarks|scripts|docs)/[\w/.-]+\.\w+)`",
                           doc.read_text()):
        if not pathlib.Path(path).exists():
            missing.append(f"{doc}: {path}")
if missing:
    print("MISSING paths referenced by docs:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("all doc-referenced module paths exist")
EOF

echo "== docstring check (core/ir.py, core/passes.py) =="
python - <<'EOF'
import inspect, sys
from repro.core import ir, passes

missing = []
for mod in (ir, passes):
    for name in mod.__all__:
        obj = getattr(mod, name)
        if not inspect.getdoc(obj):
            missing.append(f"{mod.__name__}.{name}")
        if inspect.isclass(obj):
            for m, fn in vars(obj).items():
                if callable(fn) and not m.startswith("_") \
                        and not inspect.getdoc(fn):
                    missing.append(f"{mod.__name__}.{name}.{m}")
if missing:
    print("public symbols missing docstrings:")
    print("\n".join(f"  {m}" for m in missing))
    sys.exit(1)
print("every public IR/pass symbol has a docstring")
EOF

echo "verify OK"
