"""Serving-plane benchmark: sustained QPS on a mixed query stream.

Drives :class:`repro.serve.graph_serve.GraphServer` with a seeded mixed
bfs / sssp / ppr / dist stream on the 50k/500k acceptance-scale R-MAT
(weighted), verifies **every** served answer bit-exact against the
sequential ``run(roots=root)`` oracle, and writes ``BENCH_serve.json``
(QPS, per-kind counts, served-by split, landmark pin rate, speedup over
serving the same stream sequentially) through the shared stamping helper
(:mod:`benchmarks.common`), appending a compact record to
``reports/graphs/history.jsonl``.

The serve plane and the oracle are warmed (translate + one run per
program shape) before the clock starts — the artifact measures sustained
serving throughput, not jit tracing.

``python -m benchmarks.serve``            full artifact (120 queries)
``python -m benchmarks.serve --smoke``    CI smoke: small graph, short
                                          stream, exits non-zero unless
                                          every answer matches and QPS > 0
"""
from __future__ import annotations

import sys
import time

import numpy as np

from .common import append_history, write_payload


def _build(num_vertices: int, num_edges: int, seed: int):
    from repro.core import graph as G
    rng = np.random.default_rng(seed)
    src, dst = G.rmat_edges(num_vertices, num_edges, seed=seed)
    w = rng.uniform(0.5, 2.0, size=src.shape[0]).astype(np.float32)
    return G.from_edge_list(src, dst, weights=w, num_vertices=num_vertices)


def _stream(rng, num_vertices: int, queries: int, ppr_roots) -> list[tuple]:
    """Seeded mixed stream: 50% bfs, 30% sssp, 10% ppr, 10% dist."""
    out = []
    for i in range(queries):
        r = i % 10
        if r < 5:
            out.append(("bfs", int(rng.integers(num_vertices)), None))
        elif r < 8:
            out.append(("sssp", int(rng.integers(num_vertices)), None))
        elif r < 9:
            out.append(("ppr", int(rng.choice(ppr_roots)), None))
        else:
            s, t = (int(x) for x in rng.integers(0, num_vertices, 2))
            out.append(("dist", s, t))
    return out


def collect(num_vertices: int = 50_000, num_edges: int = 500_000, *,
            queries: int = 120, seed: int = 0, landmarks: int = 8,
            slots: int = 8, slice_supersteps: int = 4) -> dict:
    from repro.core import dsl
    from repro.core.scheduler import (AdmissionPolicy, DirectionPolicy,
                                      ScheduleConfig)
    from repro.core.translator import translate
    from repro.serve.graph_serve import GraphServer

    g = _build(num_vertices, num_edges, seed)
    # pull-pinned: under vmap an 'auto' superstep lowers the direction
    # cond to execute-both-branches selects (~2x a pinned batch — see
    # run_batch's docstring), so the serving configuration pins pull for
    # throughput; answers are bit-identical across modes either way
    sched = ScheduleConfig(direction=DirectionPolicy(mode="pull"))
    adm = AdmissionPolicy(slots=slots, slice_supersteps=slice_supersteps)
    rng = np.random.default_rng(seed + 1)
    ppr_roots = rng.integers(0, num_vertices, 4)
    stream = _stream(rng, num_vertices, queries, ppr_roots)

    # ---- sequential oracle (also the warm-up: one translate + run per
    # program shape, so the timed section measures serving, not tracing)
    oracles: dict = {}
    seq_wall = 0.0
    t_lm0 = time.perf_counter()
    warm = GraphServer(g, schedule=sched, admission=adm,
                       landmarks=landmarks)
    landmark_build_s = time.perf_counter() - t_lm0
    for kind, root, _tgt in stream:
        prog = warm._program_for(kind, root)
        key = (prog, root)
        if key in oracles:
            continue
        cp = translate(prog, g, sched)
        t0 = time.perf_counter()
        vals, iters = cp.run(roots=root)
        vals = np.asarray(vals)                  # blocks until ready
        seq_wall += time.perf_counter() - t0
        oracles[key] = (vals, int(iters))
    # warm the batched slice loops (vmapped jits compile per slot count)
    for kind in ("bfs", "sssp", "dist"):
        warm.submit(kind, int(ppr_roots[0]), target=0
                    if kind == "dist" else None)
    warm.submit("ppr", int(ppr_roots[0]))
    warm.run()

    # ---- timed serve: fresh server, same compiled programs (staging
    # cache + shared loop caches keep everything warm)
    srv = GraphServer(g, schedule=sched, admission=adm,
                      landmarks=landmarks)
    t0 = time.perf_counter()
    handles = [srv.submit(kind, root, target=tgt)
               for kind, root, tgt in stream]
    srv.run()
    wall = time.perf_counter() - t0

    # ---- verify every answer against the oracle
    checked = 0
    for (kind, root, tgt), q in zip(stream, handles):
        assert q.done, (kind, root, q.status)
        ref, iters = oracles[(q.program, root)]
        if kind == "dist":
            ok = q.result == float(ref[tgt])
        else:
            ok = np.array_equal(np.asarray(q.result), ref) \
                and q.iters == iters
        if not ok:
            raise AssertionError(
                f"served answer mismatch: {kind} root={root} tgt={tgt} "
                f"served_by={q.served_by}")
        checked += 1

    by_kind: dict[str, int] = {}
    by_path: dict[str, int] = {}
    for (kind, _r, _t), q in zip(stream, handles):
        by_kind[kind] = by_kind.get(kind, 0) + 1
        by_path[q.served_by] = by_path.get(q.served_by, 0) + 1
    supersteps = sum(grp.supersteps for grp in srv._groups.values())
    dist_total = by_kind.get("dist", 0)
    pinned = sum(1 for (k, _r, _t), q in zip(stream, handles)
                 if k == "dist" and q.served_by == "landmark")
    return {
        "bench": "serve",
        "graph": {"num_vertices": num_vertices, "num_edges": num_edges,
                  "generator": f"rmat(seed={seed}), weights U(0.5,2)"},
        "admission": adm.describe(),
        "direction": sched.direction.describe(),
        "stream": {"queries": queries, "by_kind": by_kind,
                   "distinct_programs": len(srv._programs)},
        "served": {"wall_s": wall, "qps": queries / wall,
                   "supersteps": supersteps, "by_path": by_path},
        "verified": {"checked": checked, "bit_exact": True},
        "sequential": {"wall_s": seq_wall,
                       "distinct_runs": len(oracles),
                       "speedup_serve_vs_sequential": seq_wall / wall},
        "landmarks": {"k": landmarks, "build_s": landmark_build_s,
                      "dist_queries": dist_total,
                      "pinned": pinned,
                      "pin_rate": pinned / dist_total if dist_total else
                      None},
    }


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    if smoke:
        argv.remove("--smoke")
    path = argv[0] if argv else "BENCH_serve.json"
    if smoke:
        data = collect(3_000, 30_000, queries=24, landmarks=4, slots=4)
        qps = data["served"]["qps"]
        assert data["verified"]["bit_exact"] and qps > 0
        print(f"serve smoke ok: {data['verified']['checked']} answers "
              f"bit-exact, {qps:.1f} qps "
              f"(by_path={data['served']['by_path']})")
        return
    data = collect()
    write_payload(path, data)
    hist = append_history(
        {"bench": "serve",
         "qps": data["served"]["qps"],
         "wall_s": data["served"]["wall_s"],
         "queries": data["stream"]["queries"],
         "speedup_serve_vs_sequential":
             data["sequential"]["speedup_serve_vs_sequential"]},
        stamped=data)
    print(f"wrote {path} (schema {data['schema']}, commit "
          f"{data['commit']}); appended {hist}")
    s = data["served"]
    print(f"  {data['stream']['queries']} queries "
          f"({data['stream']['by_kind']}) in {s['wall_s']:.2f}s "
          f"= {s['qps']:.1f} qps sustained, {s['supersteps']} supersteps, "
          f"by_path={s['by_path']}")
    print(f"  all {data['verified']['checked']} answers bit-exact vs "
          f"sequential oracle; sequential replay "
          f"{data['sequential']['wall_s']:.2f}s -> "
          f"{data['sequential']['speedup_serve_vs_sequential']:.2f}x")
    lm = data["landmarks"]
    print(f"  landmarks k={lm['k']}: {lm['pinned']}/{lm['dist_queries']} "
          f"dist queries pinned (build {lm['build_s']:.2f}s)")


if __name__ == "__main__":
    main()
