"""Paper Table IV: graph atomic-operator extensibility comparison.

The paper counts the programmable operator surface of each accelerator
framework (GraFBoost 4, Foregraph 5, GraphOps 7, GraphSoC 17, FAgraph 25+).
We count ours from the live registry.
"""
from __future__ import annotations

import time

from repro.core.operators import OPERATOR_REGISTRY

PAPER_COUNTS = {
    "GraFBoost'18": 4,
    "Foregraph'17": 5,
    "GraphOps'16": 7,
    "GraphSoc'15": 17,
    "FAgraph (paper)": 25,
}


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    ours = len(OPERATOR_REGISTRY)
    dt = (time.perf_counter() - t0) * 1e6
    rows = [("table_iv/ours_operator_count", dt, str(ours))]
    for name, n in PAPER_COUNTS.items():
        rows.append((f"table_iv/{name.replace(' ', '_')}", 0.0, str(n)))
    assert ours >= 25, "paper claims 25+ operators; registry shrank"
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
