"""Translation-time breakdown per pipeline pass (TT decomposed).

The paper reports translation time (TT) as one number; with the IR
refactor we can decompose it: per-pass wall time for every DSL program
template, plus the share of TT spent in AOT compilation vs. the pass
pipeline. Rows:

  pass_report/<program>/<pass>_us      — one pipeline pass
  pass_report/<program>/pipeline_us    — all passes (lower + run)
  pass_report/<program>/aot_share      — AOT-compile fraction of total TT
"""
from __future__ import annotations

import time

from repro.core import dsl
from repro.core import graph as G
from repro.core.ir import lower_program
from repro.core.passes import PassContext, default_pipeline
from repro.core.scheduler import ScheduleConfig, plan
from repro.core.translator import translate


def run() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    src, dst = G.rmat_edges(2_000, 16_000, seed=0)
    g = G.from_edge_list(src, dst, num_vertices=2_000)
    cfg = ScheduleConfig()
    ctx = PassContext(
        schedule=cfg,
        plan=plan(cfg, num_vertices=g.num_vertices, num_edges=g.num_edges),
        use_pallas=False,
        num_vertices=g.num_vertices, num_edges=g.num_edges)

    for name, factory in sorted(dsl.PROGRAM_TEMPLATES.items()):
        prog = factory()
        t0 = time.perf_counter()
        ir, report = default_pipeline().run(lower_program(prog), ctx)
        pipeline_s = time.perf_counter() - t0
        for rec in report.records:
            rows.append((f"pass_report/{name}/{rec.name}_us",
                         rec.time_s * 1e6,
                         "changed" if rec.changed else "no_change"))
        rows.append((f"pass_report/{name}/pipeline_us", pipeline_s * 1e6,
                     ir.backend or "?"))

        t1 = time.perf_counter()
        compiled = translate(prog, g, cfg)
        tt = time.perf_counter() - t1
        aot_share = max(0.0, tt - pipeline_s) / tt
        rows.append((f"pass_report/{name}/TT_us", tt * 1e6,
                     f"{compiled.report.backend}"))
        rows.append((f"pass_report/{name}/aot_share", 0.0,
                     f"{aot_share:.2f}"))
        # the jaxpr analyzer's share of this translate (cache-warm after
        # the pipeline run above — the cold trace cost shows up in the
        # per-pass program-analysis_us row instead)
        bd = compiled.report.translate_breakdown
        rows.append((f"pass_report/{name}/analysis_us",
                     bd["analysis_s"] * 1e6,
                     f"diags={len(compiled.report.diagnostics)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
