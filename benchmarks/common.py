"""Shared benchmark-payload plumbing: schema stamp + history append.

Every machine-readable benchmark artifact (``BENCH_graph.json`` from
``benchmarks.run --json``, ``BENCH_serve.json`` from ``benchmarks.serve``)
is stamped through :func:`stamp` and logged through :func:`append_history`,
so the schema-version/commit fields can't drift between payloads: one
helper, two (or more) consumers.  Bump :data:`BENCH_SCHEMA` whenever any
payload's shape changes — consumers key on it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

# schema 5: the 5M scale point gained a 'checkpoint' block (durable-
# checkpoint overhead ratio + saves/write seconds at the default cadence)
BENCH_SCHEMA = 5          # bump when any BENCH_*.json payload shape changes
HISTORY_DIR = os.path.join("reports", "graphs")
HISTORY_PATH = os.path.join(HISTORY_DIR, "history.jsonl")


def memory_snapshot() -> dict:
    """Peak host RSS plus device memory where the backend exposes it.

    ``peak_host_rss_bytes`` is ``ru_maxrss`` (kilobytes on Linux,
    already bytes on macOS — normalized to bytes).  Device stats come
    from ``jax.local_devices()[0].memory_stats()`` when the backend
    implements it (TPU/GPU; CPU returns None) — the scale sweep's
    memory column, recorded per payload so the trajectory shows what a
    scale point *costs*, not just how fast it runs.
    """
    import resource
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform != "darwin":
        rss *= 1024
    snap: dict = {"peak_host_rss_bytes": int(rss)}
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
    except (ImportError, NotImplementedError, RuntimeError) as e:
        print(f"[bench] device memory stats unavailable: {e}",
              file=sys.stderr)
        return snap
    if stats:
        snap["device_bytes_in_use"] = int(stats.get("bytes_in_use", 0))
        peak = stats.get("peak_bytes_in_use")
        if peak is not None:
            snap["device_peak_bytes_in_use"] = int(peak)
    return snap


def commit() -> str:
    """Short git commit of the working tree, or 'unknown' outside a repo."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError) as e:
        print(f"[bench] git commit lookup failed: {e}", file=sys.stderr)
        return "unknown"


def stamp(payload: dict) -> dict:
    """Schema-version a payload in place so CI consumers can evolve safely."""
    payload["schema"] = BENCH_SCHEMA
    payload["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    payload["commit"] = _commit_cached()
    return payload


_COMMIT = None


def _commit_cached() -> str:
    global _COMMIT
    if _COMMIT is None:
        _COMMIT = commit()
    return _COMMIT


def append_history(entry: dict, *, stamped: dict | None = None) -> str:
    """Append one compact record to ``reports/graphs/history.jsonl``.

    ``BENCH_*.json`` files are overwritten every run; the history line
    keeps the perf trajectory across PRs (one JSON object per line).
    When ``stamped`` is given (a payload that went through :func:`stamp`),
    its schema/timestamp/commit are copied onto the entry — the entry and
    the payload it summarizes can't carry different stamps.

    The append is crash-safe: one ``O_APPEND`` write of the whole line.
    POSIX appends of a single ``write()`` are atomic with respect to
    concurrent appenders, so parallel benchmark runs (or a run killed
    mid-append) can interleave lines but never tear one — the history
    stays line-parseable JSONL.
    """
    if stamped is not None:
        entry = {**entry,
                 "schema": stamped.get("schema"),
                 "timestamp": stamped.get("timestamp"),
                 "commit": stamped.get("commit")}
    os.makedirs(HISTORY_DIR, exist_ok=True)
    line = (json.dumps(entry, sort_keys=True) + "\n").encode()
    fd = os.open(HISTORY_PATH, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    try:
        os.write(fd, line)
    finally:
        os.close(fd)
    return HISTORY_PATH


def write_payload(path: str, payload: dict) -> None:
    """Stamp + pretty-write a benchmark payload (stable key order)."""
    stamp(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
