"""Shared benchmark-payload plumbing: schema stamp + history append.

Every machine-readable benchmark artifact (``BENCH_graph.json`` from
``benchmarks.run --json``, ``BENCH_serve.json`` from ``benchmarks.serve``)
is stamped through :func:`stamp` and logged through :func:`append_history`,
so the schema-version/commit fields can't drift between payloads: one
helper, two (or more) consumers.  Bump :data:`BENCH_SCHEMA` whenever any
payload's shape changes — consumers key on it.
"""
from __future__ import annotations

import json
import os
import subprocess
import time

BENCH_SCHEMA = 2          # bump when any BENCH_*.json payload shape changes
HISTORY_DIR = os.path.join("reports", "graphs")
HISTORY_PATH = os.path.join(HISTORY_DIR, "history.jsonl")


def commit() -> str:
    """Short git commit of the working tree, or 'unknown' outside a repo."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def stamp(payload: dict) -> dict:
    """Schema-version a payload in place so CI consumers can evolve safely."""
    payload["schema"] = BENCH_SCHEMA
    payload["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    payload["commit"] = _commit_cached()
    return payload


_COMMIT = None


def _commit_cached() -> str:
    global _COMMIT
    if _COMMIT is None:
        _COMMIT = commit()
    return _COMMIT


def append_history(entry: dict, *, stamped: dict | None = None) -> str:
    """Append one compact record to ``reports/graphs/history.jsonl``.

    ``BENCH_*.json`` files are overwritten every run; the history line
    keeps the perf trajectory across PRs (one JSON object per line).
    When ``stamped`` is given (a payload that went through :func:`stamp`),
    its schema/timestamp/commit are copied onto the entry — the entry and
    the payload it summarizes can't carry different stamps.
    """
    if stamped is not None:
        entry = {**entry,
                 "schema": stamped.get("schema"),
                 "timestamp": stamped.get("timestamp"),
                 "commit": stamped.get("commit")}
    os.makedirs(HISTORY_DIR, exist_ok=True)
    with open(HISTORY_PATH, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return HISTORY_PATH


def write_payload(path: str, payload: dict) -> None:
    """Stamp + pretty-write a benchmark payload (stable key order)."""
    stamp(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
