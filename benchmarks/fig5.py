"""Paper Fig. 5: development-cost stages (program preparation, system
compilation, environment deployment) for the light-weight path vs the
general-purpose strawman."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import algorithms as alg
from repro.core import dsl
from repro.core import graph as G
from repro.core.comm import CommManager
from repro.core.preprocess import load_paper_graph
from repro.core.scheduler import ScheduleConfig
from repro.core.translator import translate


def run() -> list[tuple[str, float, str]]:
    rows = []
    g_host = load_paper_graph("email-Eu-core", cache_dir="reports/graphs")

    # stage 1: program preparation = building the DSL program object
    t0 = time.perf_counter()
    program = dsl.bfs_program(alg.INT_MAX)
    prep = time.perf_counter() - t0

    # stage 2: system compilation = light-weight translation + AOT staging
    t0 = time.perf_counter()
    prog = translate(program, g_host, ScheduleConfig(backend="sparse"))
    compile_s = time.perf_counter() - t0

    # stage 3: environment deployment = transport + first superstep
    comm = CommManager()
    t0 = time.perf_counter()
    g_dev = comm.transport(g_host)
    values, active = prog.init_state(roots=0)
    values, active = prog.superstep(values, active)
    jax.block_until_ready(values)
    deploy = time.perf_counter() - t0

    rows.append(("fig5/prepare_s", prep * 1e6, f"{prep * 1e3:.2f}ms"))
    rows.append(("fig5/compile_s", compile_s * 1e6, f"{compile_s:.2f}s"))
    rows.append(("fig5/deploy_s", deploy * 1e6, f"{deploy * 1e3:.1f}ms"))
    total = prep + compile_s + deploy
    rows.append(("fig5/total_s", total * 1e6, f"{total:.2f}s"))
    # the paper's qualitative claim: compilation dominates but stays small
    # ("within tens of seconds"), vs hours for synthesis flows
    rows.append(("fig5/paper_claim_tens_of_seconds", 0.0,
                 str(bool(total < 60))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
