"""Direction-optimization crossover: BFS push vs pull vs auto.

The tentpole claim behind the dual-mode engine: on a frontier algorithm the
pull engine streams all E edges every superstep, while the
direction-optimized engine pays ~Σ out_deg(frontier) on push supersteps —
so BFS total edge work drops from O(diameter·E) toward O(E).  Since the
frontier-compacted forward-ELL engine the claim must hold in *wall time*
too, not just in the traversal counter.  Per R-MAT scale this module
measures:

* wall-clock per full BFS run and MTEPS (traversed edges / second) for
  ``direction='pull' | 'push' | 'auto'``;
* the algorithmic edge-traversal counters from ``report.run_stats``
  (E per pull superstep, m_f per push superstep), the direction-switch
  counts, and the compacted vs dense-fallback push superstep split;
* translate time (TT) per mode, its preprocess/passes/AOT breakdown, and
  the repeat-translate time on the cached graph (the preprocessing +
  staging caches at work);
* measured per-edge engine costs — the pull stream's ns/edge vs the
  compacted push kernel's ns/slot — from which the compaction/fallback
  crossover is re-derived (this is what calibrates the
  ``DirectionPolicy`` defaults and ``push_capacity_tiers``).

``collect()`` returns one scale's dict; ``collect_sweep()`` runs the
10k/50k/200k ladder (the ``benchmarks/run.py --json`` payload →
``BENCH_graph.json``); ``run()`` renders the standard CSV rows.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import algorithms as alg
from repro.core import dsl
from repro.core import graph as G
from repro.core.scheduler import DirectionPolicy, ScheduleConfig
from repro.core.translator import translate

MODES = ("pull", "push", "auto")

# the multi-scale ladder: (num_vertices, num_edges); 50k/500k is the
# acceptance scale whose results surface at the payload's top level
SWEEP_SCALES = ((10_000, 100_000), (50_000, 500_000), (200_000, 2_000_000))


def _time_run(prog, root, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        values, iters = prog.run(roots=root)
        jax.block_until_ready(values)
        best = min(best, time.perf_counter() - t0)
    return best, values, iters


def _time_interleaved(progs: dict, root, repeats=5) -> dict:
    """Best-of-``repeats`` wall per program, *interleaved* round-robin.

    Timing each candidate in its own contiguous block lets multi-ms
    drift on a shared 2-core box (background compiles, cache state,
    scheduler phase) land entirely on one candidate and skew ratios by
    2x; round-robin rounds expose every candidate to the same drift, so
    min-per-candidate ratios stay meaningful.  One warm-up run each
    (compile + cache fill) is excluded.
    """
    out = {}
    for name, prog in progs.items():
        values, _ = prog.run(roots=root)              # warm-up, untimed
        jax.block_until_ready(values)
        out[name] = float("inf")
    for _ in range(repeats):
        for name, prog in progs.items():
            t0 = time.perf_counter()
            values, _ = prog.run(roots=root)
            jax.block_until_ready(values)
            out[name] = min(out[name], time.perf_counter() - t0)
    return out


def _time_fn(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_engine_costs(g, prog_pull, prog_push, root, width) -> dict:
    """Per-edge engine costs + the re-derived compaction crossover.

    Times one pull superstep (dense O(E) stream) and one *compacted* push
    superstep (root-only frontier → smallest capacity tier), then derives
    the row count at which compacted push cost would reach pull cost —
    the measurement behind the engine's tier/fallback boundary and the
    recalibrated alpha/beta defaults (see ``DirectionPolicy``).
    """
    v0, a0 = prog_pull.init_state(roots=root)
    t_pull = _time_fn(prog_pull.superstep, v0, a0)
    t_push = _time_fn(prog_push.superstep_push, v0, a0)
    tiers = prog_push.report.push_tiers
    costs = {
        "pull_superstep_s": t_pull,
        "pull_ns_per_edge": t_pull / max(g.num_edges, 1) * 1e9,
        "push_compacted_superstep_s": t_push,
    }
    if tiers:
        small = tiers[0]
        # upper bound: the whole small-tier superstep charged to its slots
        w = width
        ns_per_slot = t_push / (small * w) * 1e9
        costs.update({
            "push_tiers_rows": list(tiers),
            "push_ns_per_slot_upper": ns_per_slot,
            # rows where compacted cost would reach one pull superstep:
            # beyond this the engine's dense fallback is the right call
            "derived_crossover_rows": int(t_pull / (ns_per_slot * 1e-9 * w)),
        })
    return costs


def collect(num_vertices: int = 50_000, num_edges: int = 500_000,
            seed: int = 0, root: int = 0, repeats: int = 5) -> dict:
    """Run the BFS direction sweep at one scale; JSON-serializable dict."""
    src, dst = G.rmat_edges(num_vertices, num_edges, seed=seed)
    g = G.from_edge_list(src, dst, num_vertices=num_vertices)
    out = {
        "graph": {"num_vertices": g.num_vertices, "num_edges": g.num_edges,
                  "generator": f"rmat(seed={seed})"},
        "modes": {},
    }
    program = dsl.bfs_program(alg.INT_MAX)
    progs = {}
    repeat_s = {}
    push_ell_width = ScheduleConfig().push_ell_width
    for mode in MODES:
        cfg = ScheduleConfig(direction=DirectionPolicy(mode=mode))
        progs[mode] = translate(program, g, cfg)
        # repeat translate of identical inputs: preprocessing + staging
        # caches make this milliseconds (the acceptance criterion)
        t0 = time.perf_counter()
        translate(program, g, cfg)
        repeat_s[mode] = time.perf_counter() - t0
    # the bitmap-vs-dense pull-plane A/B the verify-script regression
    # guard pins: the forced block-skipping sweep vs the flat dense sweep
    # the shipped default resolves to on this (XLA) backend
    progs["pull_bitmap"] = translate(
        program, g, ScheduleConfig(direction=DirectionPolicy(mode="pull"),
                                   pull_sweep="bitmap"))
    progs["pull_dense"] = translate(
        program, g, ScheduleConfig(direction=DirectionPolicy(mode="pull"),
                                   pull_sweep="dense"))
    walls = _time_interleaved(progs, root, repeats)
    baseline = None
    for mode in MODES:
        prog = progs[mode]
        levels, iters = prog.run(roots=root)
        lv = np.asarray(levels)
        if baseline is None:
            baseline = lv
        else:                      # all modes must agree bit-exactly
            assert np.array_equal(baseline, lv), f"{mode} diverged from pull"
        te = alg.traversed_edges(g, levels)
        out["modes"][mode] = {
            "wall_s": walls[mode],
            "iters": int(iters),
            "mteps": te / walls[mode] / 1e6,
            "translate_time_s": prog.report.translate_time_s,
            "translate_repeat_s": repeat_s[mode],
            "translate_breakdown": prog.report.translate_breakdown,
            "backend": prog.report.backend,
            "push_layout": prog.report.push_layout,
            "pull_sweep": prog.report.pull_sweep,
            **prog.report.run_stats,
        }
    pull, auto = out["modes"]["pull"], out["modes"]["auto"]
    bstats = progs["pull_bitmap"].last_run_stats
    out["pull_plane"] = {
        "default_sweep": out["modes"]["pull"]["pull_sweep"],
        "dense_wall_s": walls["pull_dense"],
        "bitmap_wall_s": walls["pull_bitmap"],
        "wall_ratio_bitmap_vs_dense":
            walls["pull_bitmap"] / walls["pull_dense"],
        "blocks_total": progs["pull_bitmap"].report.pull_blocks_total,
        "blocks_swept": bstats["pull_blocks_swept"],
        "blocks_skipped": bstats["pull_blocks_skipped"],
    }
    out["crossover"] = {
        "traversal_reduction_auto_vs_pull":
            pull["edges_traversed"] / max(auto["edges_traversed"], 1),
        "speedup_auto_vs_pull": pull["wall_s"] / auto["wall_s"],
        "reached": int((baseline < alg.INT_MAX).sum()),
        **_measure_engine_costs(g, progs["pull"], progs["push"], root,
                                push_ell_width),
    }
    return out


def collect_sweep(scales=SWEEP_SCALES, seed: int = 0, root: int = 0,
                  repeats: int = 5) -> dict:
    """Multi-scale sweep; the 50k acceptance scale stays at the top level
    (back-compat for CI consumers of ``BENCH_graph.json``), every scale
    lands under ``sweep`` keyed by vertex count."""
    sweep = {}
    primary = None
    for v, e in scales:
        data = collect(num_vertices=v, num_edges=e, seed=seed, root=root,
                       repeats=repeats)
        sweep[str(v)] = data
        if (v, e) == (50_000, 500_000):
            primary = data
    out = dict(primary if primary is not None
               else sweep[str(scales[-1][0])])
    out["sweep"] = {
        k: {"graph": d["graph"],
            "mteps": {m: d["modes"][m]["mteps"] for m in MODES},
            "wall_s": {m: d["modes"][m]["wall_s"] for m in MODES},
            "speedup_auto_vs_pull": d["crossover"]["speedup_auto_vs_pull"],
            "traversal_reduction_auto_vs_pull":
                d["crossover"]["traversal_reduction_auto_vs_pull"]}
        for k, d in sweep.items()}
    return out


def collect_pe_sweep(max_pes: int, num_vertices: int = 50_000,
                     num_edges: int = 500_000, seed: int = 0, root: int = 0,
                     repeats: int = 3) -> dict:
    """Per-PE scaling of the sharded push engine (BFS, auto direction).

    For pes ∈ {1, 2, 4, … max_pes} (powers of two, clamped to the device
    pool): wall time, the direction/exchange counters from
    ``report.run_stats`` (``exchange_supersteps`` / ``exchange_bytes`` are
    the *executed* collectives, recorded by the run loop), the static
    per-PE interval balance (``push_pe_rows`` / ``push_pe_edges``), and
    the ``CommManager``'s accumulated totals.  The run-stat counters are
    per-run; the comm totals accumulate over every timed repeat (that is
    what they measure — the accumulation plane), so the payload records
    ``repeats`` to keep the two reconcilable:
    ``comm_collective_bytes_total == repeats · exchange_bytes``.
    Results are asserted bit-identical to pes=1 before anything is
    recorded.  Run via ``python -m benchmarks.run --pes N`` (which
    forces N host devices before jax initializes); payload lands under
    ``pe_sweep`` in ``BENCH_graph.json``.
    """
    import jax as _jax

    from repro.core.comm import CommManager

    src, dst = G.rmat_edges(num_vertices, num_edges, seed=seed)
    g = G.from_edge_list(src, dst, num_vertices=num_vertices)
    pes_ladder = [1]
    while pes_ladder[-1] * 2 <= min(max_pes, len(_jax.devices())):
        pes_ladder.append(pes_ladder[-1] * 2)
    out = {"graph": {"num_vertices": g.num_vertices,
                     "num_edges": g.num_edges,
                     "generator": f"rmat(seed={seed})"},
           # comm_* totals below accumulate over this many timed runs;
           # the run_stats counters in the same record are per-run
           "repeats": repeats,
           "per_pes": {}}
    baseline = None
    for pes in pes_ladder:
        comm = CommManager()
        prog = translate(dsl.bfs_program(alg.INT_MAX), g,
                         ScheduleConfig(pes=pes), comm)
        wall_s, levels, iters = _time_run(prog, root, repeats)
        lv = np.asarray(levels)
        if baseline is None:
            baseline = lv
        else:
            assert np.array_equal(baseline, lv), f"pes={pes} diverged"
        te = alg.traversed_edges(g, levels)
        out["per_pes"][str(pes)] = {
            "wall_s": wall_s,
            "mteps": te / wall_s / 1e6,
            "iters": int(iters),
            "report_pes": prog.report.pes,
            "exchange_plane": prog.report.exchange_plane,
            "est_collective_bytes": prog.report.est_collective_bytes,
            "push_pe_rows": list(prog.report.push_pe_rows or ()),
            "push_pe_edges": list(prog.report.push_pe_edges or ()),
            "comm_collective_bytes_total":
                comm.stats.collective_bytes_total,
            "comm_collective_supersteps": comm.stats.collective_supersteps,
            **prog.report.run_stats,
        }
    one = out["per_pes"]["1"]["wall_s"]
    out["speedup_vs_1pe"] = {p: one / d["wall_s"]
                            for p, d in out["per_pes"].items()}
    return out


def run() -> list[tuple[str, float, str]]:
    """CSV rows for the benchmark driver (smaller default for quick runs)."""
    data = collect(num_vertices=20_000, num_edges=200_000, repeats=2)
    rows = []
    for mode, m in data["modes"].items():
        rows.append((f"direction/bfs_{mode}_wall", m["wall_s"] * 1e6,
                     f"{m['mteps']:.1f}MTEPS"))
        rows.append((f"direction/bfs_{mode}_edges_traversed", 0.0,
                     str(m["edges_traversed"])))
        rows.append((f"direction/bfs_{mode}_supersteps", 0.0,
                     f"push={m['push_supersteps']}"
                     f"(compacted={m['push_compacted_supersteps']}),"
                     f"pull={m['pull_supersteps']}"))
        rows.append((f"direction/bfs_{mode}_translate_repeat",
                     m["translate_repeat_s"] * 1e6, "cached"))
    c = data["crossover"]
    rows.append(("direction/traversal_reduction_auto_vs_pull", 0.0,
                 f"{c['traversal_reduction_auto_vs_pull']:.2f}x"))
    rows.append(("direction/speedup_auto_vs_pull", 0.0,
                 f"{c['speedup_auto_vs_pull']:.2f}x"))
    rows.append(("direction/pull_ns_per_edge", 0.0,
                 f"{c['pull_ns_per_edge']:.1f}ns"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
