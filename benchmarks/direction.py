"""Direction-optimization crossover: BFS push vs pull vs auto.

The tentpole claim behind the dual-mode engine: on a frontier algorithm the
pull engine streams all E edges every superstep, while the
direction-optimized engine pays ~Σ out_deg(frontier) on push supersteps —
so BFS total edge work drops from O(diameter·E) toward O(E).  This entry
measures, on an R-MAT graph matching the acceptance setup (V≈50k, E≈500k):

* wall-clock per full BFS run and MTEPS (traversed edges / second) for
  ``direction='pull' | 'push' | 'auto'``;
* the algorithmic edge-traversal counters from ``report.run_stats``
  (E per pull superstep, m_f per push superstep) and the direction-switch
  counts, demonstrating the crossover;
* translate time (TT) per mode.

``collect()`` returns the full dict (the ``benchmarks/run.py --json``
payload → ``BENCH_graph.json``); ``run()`` renders the standard CSV rows.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import algorithms as alg
from repro.core import dsl
from repro.core import graph as G
from repro.core.scheduler import DirectionPolicy, ScheduleConfig
from repro.core.translator import translate

MODES = ("pull", "push", "auto")


def _time_run(prog, root, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        values, iters = prog.run(roots=root)
        jax.block_until_ready(values)
        best = min(best, time.perf_counter() - t0)
    return best, values, iters


def collect(num_vertices: int = 50_000, num_edges: int = 500_000,
            seed: int = 0, root: int = 0, repeats: int = 3) -> dict:
    """Run the BFS direction sweep; returns the JSON-serializable payload."""
    src, dst = G.rmat_edges(num_vertices, num_edges, seed=seed)
    g = G.from_edge_list(src, dst, num_vertices=num_vertices)
    out = {
        "graph": {"num_vertices": g.num_vertices, "num_edges": g.num_edges,
                  "generator": f"rmat(seed={seed})"},
        "modes": {},
    }
    baseline = None
    for mode in MODES:
        prog = translate(
            dsl.bfs_program(alg.INT_MAX), g,
            ScheduleConfig(direction=DirectionPolicy(mode=mode)))
        wall_s, levels, iters = _time_run(prog, root, repeats)
        lv = np.asarray(levels)
        if baseline is None:
            baseline = lv
        else:                      # all modes must agree bit-exactly
            assert np.array_equal(baseline, lv), f"{mode} diverged from pull"
        te = alg.traversed_edges(g, levels)
        out["modes"][mode] = {
            "wall_s": wall_s,
            "iters": int(iters),
            "mteps": te / wall_s / 1e6,
            "translate_time_s": prog.report.translate_time_s,
            "backend": prog.report.backend,
            **prog.report.run_stats,
        }
    pull, auto = out["modes"]["pull"], out["modes"]["auto"]
    out["crossover"] = {
        "traversal_reduction_auto_vs_pull":
            pull["edges_traversed"] / max(auto["edges_traversed"], 1),
        "speedup_auto_vs_pull": pull["wall_s"] / auto["wall_s"],
        "reached": int((baseline < alg.INT_MAX).sum()),
    }
    return out


def run() -> list[tuple[str, float, str]]:
    """CSV rows for the benchmark driver (smaller default for quick runs)."""
    data = collect(num_vertices=20_000, num_edges=200_000, repeats=2)
    rows = []
    for mode, m in data["modes"].items():
        rows.append((f"direction/bfs_{mode}_wall", m["wall_s"] * 1e6,
                     f"{m['mteps']:.1f}MTEPS"))
        rows.append((f"direction/bfs_{mode}_edges_traversed", 0.0,
                     str(m["edges_traversed"])))
        rows.append((f"direction/bfs_{mode}_supersteps", 0.0,
                     f"push={m['push_supersteps']},pull={m['pull_supersteps']}"))
    c = data["crossover"]
    rows.append(("direction/traversal_reduction_auto_vs_pull", 0.0,
                 f"{c['traversal_reduction_auto_vs_pull']:.2f}x"))
    rows.append(("direction/speedup_auto_vs_pull", 0.0,
                 f"{c['speedup_auto_vs_pull']:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
