"""Benchmark driver: one module per paper table/figure.

Default mode prints ``name,us_per_call,derived`` CSV rows for the selected
modules.  ``--json [path]`` runs the direction-optimization graph benchmark
as a multi-scale sweep (10k/50k/200k-vertex R-MAT, 10x edges each) and
writes the machine-readable payload — BFS MTEPS and wall time for
push/pull/auto per scale, edge-traversal / direction-switch / compaction
counters, translate-time breakdowns (incl. cached repeat), and measured
per-edge engine costs — to ``BENCH_graph.json`` (CI's perf artifact).
The 50k/500k acceptance scale keeps its fields at the payload top level.
"""
from __future__ import annotations

import json
import sys


def _run_csv(only: list[str]) -> None:
    from . import (direction, fig5, lm_step, pass_report, roofline, table_iv,
                   table_v)
    mods = {
        "table_iv": table_iv,
        "table_v": table_v,
        "fig5": fig5,
        "lm_step": lm_step,
        "roofline": roofline,
        "pass_report": pass_report,
        "direction": direction,
    }
    only = only or list(mods)
    print("name,us_per_call,derived")
    for name in only:
        for row in mods[name].run():
            print(",".join(str(x) for x in row), flush=True)


def _run_json(path: str) -> None:
    from . import direction
    data = direction.collect_sweep()
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    c = data["crossover"]
    print(f"wrote {path}")
    for mode, m in data["modes"].items():
        print(f"  bfs[{mode}] @50k: {m['mteps']:.1f} MTEPS, "
              f"{m['edges_traversed']} edges traversed, "
              f"TT={m['translate_time_s']:.2f}s "
              f"(repeat {m['translate_repeat_s'] * 1e3:.0f}ms)")
    print(f"  auto vs pull @50k: "
          f"{c['traversal_reduction_auto_vs_pull']:.2f}x fewer "
          f"edge-traversals, {c['speedup_auto_vs_pull']:.2f}x wall-clock")
    for v, s in sorted(data["sweep"].items(), key=lambda kv: int(kv[0])):
        print(f"  sweep V={v}: auto {s['mteps']['auto']:.1f} MTEPS, "
              f"{s['speedup_auto_vs_pull']:.2f}x vs pull, "
              f"{s['traversal_reduction_auto_vs_pull']:.2f}x fewer edges")


def main() -> None:
    argv = sys.argv[1:]
    if "--json" in argv:
        argv.remove("--json")
        _run_json(argv[0] if argv else "BENCH_graph.json")
        return
    _run_csv(argv)


if __name__ == '__main__':
    main()
