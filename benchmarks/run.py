"""Benchmark driver: one module per paper table/figure.

Default mode prints ``name,us_per_call,derived`` CSV rows for the selected
modules.  ``--json [path]`` runs the direction-optimization graph benchmark
as a multi-scale sweep (10k/50k/200k-vertex R-MAT, 10x edges each) and
writes the machine-readable payload — BFS MTEPS and wall time for
push/pull/auto per scale, edge-traversal / direction-switch / compaction /
pull-block-skip counters, the bitmap-vs-dense pull-plane A/B, translate-time
breakdowns (incl. cached repeat), and measured per-edge engine costs — to
``BENCH_graph.json`` (CI's perf artifact).  The payload is
schema-versioned (``schema``/``timestamp``/``commit``) and every ``--json``
run also appends a compact record to ``reports/graphs/history.jsonl`` so
the perf trajectory accumulates across PRs instead of being overwritten.
The 50k/500k acceptance scale keeps its fields at the payload top level.

``--pes N`` runs the multi-PE scaling sweep of the sharded push engine
(BFS auto at pes ∈ {1, 2, …, N} on N forced host devices — the flag must
be handled before jax initializes, which is why this driver imports the
benchmark modules lazily) and merges the payload under ``pe_sweep`` in
``BENCH_graph.json``: per-PE wall time / MTEPS, the executed exchange
bytes and supersteps recorded by the run loop, and the interval balance.
``--pes`` is a separate invocation from ``--json`` (enforced): forced
host devices change XLA:CPU scheduling, so the single-PE acceptance
sweep must never run under them.

``--scale`` runs the out-of-core scale sweep (``benchmarks.scale``:
partitioned BFS/SSSP over 500k/5M/20M-edge R-MAT containers under a
partition budget smaller than the edge stream) and merges the payload
under ``scale_sweep`` — per scale: MTEPS, bytes streamed h2d, partitions
skipped, transfer/compute overlap efficiency, and a peak-memory
snapshot.  Each scale point also appends its own history record carrying
a ``scale`` field, so the trajectory file distinguishes the resident
acceptance sweep (``scale: "50k/500k"``) from the streamed points.
"""
from __future__ import annotations

import json
import os
import sys

from .common import (BENCH_SCHEMA, append_history, memory_snapshot,  # noqa: F401
                     stamp as _stamp)


def _append_history(payload: dict) -> str:
    """Append this sweep's headline numbers to reports/graphs/history.jsonl.

    ``BENCH_graph.json`` is overwritten every run; the history line keeps
    the perf trajectory across PRs.  Stamping (schema/timestamp/commit)
    rides :func:`benchmarks.common.append_history` — the same helper the
    serving benchmark uses, so the two payloads can't drift.
    """
    entry = {
        "mteps": {m: d["mteps"] for m, d in payload.get("modes", {}).items()},
        "wall_s": {m: d["wall_s"]
                   for m, d in payload.get("modes", {}).items()},
        "speedup_auto_vs_pull":
            payload.get("crossover", {}).get("speedup_auto_vs_pull"),
        "traversal_reduction_auto_vs_pull":
            payload.get("crossover", {}).get(
                "traversal_reduction_auto_vs_pull"),
        "pull_plane": payload.get("pull_plane"),
        # every history record names its scale so the streamed scale-sweep
        # points and this resident acceptance sweep stay distinguishable
        "scale": "50k/500k",
    }
    return append_history(entry, stamped=payload)


def _run_csv(only: list[str]) -> None:
    from . import (direction, fig5, lm_step, pass_report, roofline, table_iv,
                   table_v)
    mods = {
        "table_iv": table_iv,
        "table_v": table_v,
        "fig5": fig5,
        "lm_step": lm_step,
        "roofline": roofline,
        "pass_report": pass_report,
        "direction": direction,
    }
    only = only or list(mods)
    print("name,us_per_call,derived")
    for name in only:
        for row in mods[name].run():
            print(",".join(str(x) for x in row), flush=True)


def _run_json(path: str) -> None:
    from . import direction
    data = direction.collect_sweep()
    data["memory"] = memory_snapshot()
    _stamp(data)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    hist = _append_history(data)
    c = data["crossover"]
    print(f"wrote {path} (schema {data['schema']}, commit {data['commit']}); "
          f"appended {hist}")
    p = data.get("pull_plane", {})
    if p:
        print(f"  pull plane (default={p['default_sweep']}): "
              f"bitmap {p['bitmap_wall_s']*1e3:.1f} ms vs "
              f"dense {p['dense_wall_s']*1e3:.1f} ms "
              f"({p['wall_ratio_bitmap_vs_dense']:.2f}x), "
              f"blocks {p['blocks_swept']}/{p['blocks_skipped']} "
              f"swept/skipped")
    for mode, m in data["modes"].items():
        print(f"  bfs[{mode}] @50k: {m['mteps']:.1f} MTEPS, "
              f"{m['edges_traversed']} edges traversed, "
              f"TT={m['translate_time_s']:.2f}s "
              f"(repeat {m['translate_repeat_s'] * 1e3:.0f}ms)")
    print(f"  auto vs pull @50k: "
          f"{c['traversal_reduction_auto_vs_pull']:.2f}x fewer "
          f"edge-traversals, {c['speedup_auto_vs_pull']:.2f}x wall-clock")
    for v, s in sorted(data["sweep"].items(), key=lambda kv: int(kv[0])):
        print(f"  sweep V={v}: auto {s['mteps']['auto']:.1f} MTEPS, "
              f"{s['speedup_auto_vs_pull']:.2f}x vs pull, "
              f"{s['traversal_reduction_auto_vs_pull']:.2f}x fewer edges")


def _run_pes(max_pes: int, path: str) -> None:
    from . import direction
    data = direction.collect_pe_sweep(max_pes)
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["pe_sweep"] = data
    _stamp(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"merged pe_sweep into {path}")
    for pes, d in sorted(data["per_pes"].items(), key=lambda kv: int(kv[0])):
        print(f"  pes={pes}: {d['wall_s']*1e3:.1f} ms "
              f"({data['speedup_vs_1pe'][pes]:.2f}x vs 1 PE), "
              f"{d['mteps']:.1f} MTEPS, push={d['push_supersteps']}"
              f"(compacted={d['push_compacted_supersteps']}), "
              f"exchange {d['exchange_supersteps']} supersteps / "
              f"{d['exchange_bytes']} B")


def _run_scale(path: str) -> None:
    from . import scale
    data = scale.collect_scale_sweep()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["scale_sweep"] = data
    _stamp(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"merged scale_sweep into {path}")
    for label, s in data["scales"].items():
        b = s["bfs"]
        append_history({
            "scale": label,
            "mteps": {"bfs": b["mteps"]},
            "wall_s": {"bfs": b["wall_s"]},
            "partition_bytes_h2d": b["partition_bytes_h2d"],
            "partitions_skipped": b["partitions_skipped"],
            "overlap_efficiency": b["overlap_efficiency"],
            "peak_host_rss_bytes": s["memory"]["peak_host_rss_bytes"],
        }, stamped=payload)
        check = s.get("resident_crosscheck_bitexact")
        extra = "" if check is None else f", resident cross-check={check}"
        print(f"  scale {label} (V={s['num_vertices']}): "
              f"bfs {b['mteps']:.1f} MTEPS in {b['wall_s']:.2f}s, "
              f"{b['partition_bytes_h2d'] / 1e6:.1f} MB h2d, "
              f"{b['partitions_skipped']}/{b['partitions_swept']} "
              f"parts skipped/swept, "
              f"overlap {b['overlap_efficiency']:.2f}{extra}")
        if "sssp" in s:
            ss = s["sssp"]
            print(f"  scale {label}: sssp {ss['mteps']:.1f} MTEPS in "
                  f"{ss['wall_s']:.2f}s, "
                  f"{ss['partition_bytes_h2d'] / 1e6:.1f} MB h2d")
    print(f"  appended {len(data['scales'])} scale records to history")


def main() -> None:
    argv = sys.argv[1:]
    max_pes = None
    if "--pes" in argv:
        i = argv.index("--pes")
        try:
            max_pes = int(argv[i + 1])
        except (IndexError, ValueError):
            print("error: --pes needs a device count (--pes N)",
                  file=sys.stderr)
            raise SystemExit(2)
        if max_pes < 1:
            print(f"error: --pes must be >= 1, got {max_pes}",
                  file=sys.stderr)
            raise SystemExit(2)
        del argv[i:i + 2]
        # must land before the lazy benchmark imports pull in jax; pin
        # the cpu platform too — forced host devices only exist on the
        # CPU backend, so on an accelerator host the sweep would
        # silently clamp to the single accelerator otherwise
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import re
        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"xla_force_host_platform_device_count=(\d+)", flags)
        if m is None:
            os.environ["XLA_FLAGS"] = (
                flags
                + f" --xla_force_host_platform_device_count={max_pes}"
            ).strip()
        elif int(m.group(1)) < max_pes:
            # a smaller inherited count would silently truncate the sweep
            print(f"error: XLA_FLAGS already forces "
                  f"{m.group(1)} host devices (< --pes {max_pes}); "
                  "unset it or lower --pes", file=sys.stderr)
            raise SystemExit(2)
    if "--json" in argv:
        argv.remove("--json")
        if max_pes is not None:
            # forced host devices are a debug configuration that changes
            # XLA:CPU scheduling — the single-PE acceptance sweep must
            # never run under it, or the artifact's headline numbers stop
            # being comparable across CI runs.  Run the two sweeps as
            # separate invocations; --pes merges into the existing file.
            print("error: --pes and --json are separate runs "
                  "(run --json first, then --pes N to merge pe_sweep)",
                  file=sys.stderr)
            raise SystemExit(2)
        if "--scale" in argv:
            print("error: --json and --scale are separate runs "
                  "(run --json first, then --scale to merge scale_sweep)",
                  file=sys.stderr)
            raise SystemExit(2)
        _run_json(argv[0] if argv else "BENCH_graph.json")
        return
    if "--scale" in argv:
        argv.remove("--scale")
        if max_pes is not None:
            print("error: --pes and --scale are separate runs",
                  file=sys.stderr)
            raise SystemExit(2)
        _run_scale(argv[0] if argv else "BENCH_graph.json")
        return
    if max_pes is not None:
        _run_pes(max_pes, argv[0] if argv else "BENCH_graph.json")
        return
    _run_csv(argv)


if __name__ == '__main__':
    main()
