"""Benchmark driver: one module per paper table/figure.

Default mode prints ``name,us_per_call,derived`` CSV rows for the selected
modules.  ``--json [path]`` runs the direction-optimization graph benchmark
at the acceptance scale (V≈50k, E≈500k R-MAT) and writes the machine-
readable payload — BFS MTEPS for push/pull/auto, per-mode edge-traversal
and direction-switch counters, and translate time — to ``BENCH_graph.json``
(CI's perf artifact).
"""
from __future__ import annotations

import json
import sys


def _run_csv(only: list[str]) -> None:
    from . import (direction, fig5, lm_step, pass_report, roofline, table_iv,
                   table_v)
    mods = {
        "table_iv": table_iv,
        "table_v": table_v,
        "fig5": fig5,
        "lm_step": lm_step,
        "roofline": roofline,
        "pass_report": pass_report,
        "direction": direction,
    }
    only = only or list(mods)
    print("name,us_per_call,derived")
    for name in only:
        for row in mods[name].run():
            print(",".join(str(x) for x in row), flush=True)


def _run_json(path: str) -> None:
    from . import direction
    data = direction.collect()
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    c = data["crossover"]
    print(f"wrote {path}")
    for mode, m in data["modes"].items():
        print(f"  bfs[{mode}]: {m['mteps']:.1f} MTEPS, "
              f"{m['edges_traversed']} edges traversed, "
              f"TT={m['translate_time_s']:.2f}s")
    print(f"  auto vs pull: {c['traversal_reduction_auto_vs_pull']:.2f}x "
          f"fewer edge-traversals, {c['speedup_auto_vs_pull']:.2f}x wall-clock")


def main() -> None:
    argv = sys.argv[1:]
    if "--json" in argv:
        argv.remove("--json")
        _run_json(argv[0] if argv else "BENCH_graph.json")
        return
    _run_csv(argv)


if __name__ == '__main__':
    main()
