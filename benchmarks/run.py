"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys


def main() -> None:
    from . import fig5, lm_step, pass_report, roofline, table_iv, table_v
    mods = {
        "table_iv": table_iv,
        "table_v": table_v,
        "fig5": fig5,
        "lm_step": lm_step,
        "roofline": roofline,
        "pass_report": pass_report,
    }
    only = sys.argv[1:] or list(mods)
    print("name,us_per_call,derived")
    for name in only:
        for row in mods[name].run():
            print(",".join(str(x) for x in row), flush=True)


if __name__ == '__main__':
    main()
