"""Aggregate the dry-run sweep JSONs into the §Roofline table (CSV + md)."""
from __future__ import annotations

import glob
import json
import os

HBM_GIB = 16.0


def load_cells(pattern: str = "reports/cell_*.json") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(pattern)):
        with open(f) as fh:
            cells.extend(json.load(fh))
    return cells


def run() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    cells = load_cells()
    if not cells:
        rows.append(("roofline/no_sweep_data_yet", 0.0, "run reports/run_sweep.sh"))
        return rows
    n_ok = sum(c["status"] == "ok" for c in cells)
    n_skip = sum(c["status"] == "skip" for c in cells)
    n_err = sum(c["status"] == "error" for c in cells)
    rows.append(("roofline/cells_ok", 0.0, str(n_ok)))
    rows.append(("roofline/cells_skip", 0.0, str(n_skip)))
    rows.append(("roofline/cells_error", 0.0, str(n_err)))
    for c in cells:
        key = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] == "skip":
            rows.append((key, 0.0, "skip"))
            continue
        if c["status"] == "error":
            rows.append((key, 0.0, "ERROR " + c.get("error", "")[:60]))
            continue
        fits = c["live_bytes_per_device"] / 2**30
        detail = f"live={fits:.2f}GiB"
        if "roofline" in c:
            r = c["roofline"]
            detail += (f" dom={r['dominant']}"
                       f" c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s"
                       f" l={r['collective_s']:.3f}s"
                       f" useful={r['useful_ratio']:.2f}")
        rows.append((key, c.get("compile_s", 0.0) * 1e6, detail))
    return rows


def markdown_table(cells: list[dict]) -> str:
    """Full §Roofline markdown (used to build EXPERIMENTS.md)."""
    lines = ["| arch | shape | mesh | live GiB | fits | dominant | compute s "
             "| memory s | collective s | MODEL_FLOPS | useful |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | "
                         f"{c['status']} | | | | | | |")
            continue
        r = c.get("roofline", {})
        live = c["live_bytes_per_device"] / 2**30
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {live:.2f} | "
            f"{'✓' if c['fits_16gb'] else '✗'} | {r.get('dominant', '—')} | "
            f"{r.get('compute_s', 0):.4f} | {r.get('memory_s', 0):.4f} | "
            f"{r.get('collective_s', 0):.4f} | "
            f"{r.get('model_flops', 0):.3e} | "
            f"{r.get('useful_ratio', 0):.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
