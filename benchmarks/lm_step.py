"""LM substrate micro-benchmark: smoke-scale train/decode step throughput
on the host CPU (substrate health; not a paper table)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models.model import LModel
from repro.serve.decode import make_serve_fns
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


def run() -> list[tuple[str, float, str]]:
    rows = []
    for arch in ("qwen3-8b", "falcon-mamba-7b", "grok-1-314b"):
        cfg = smoke_config(arch)
        model = LModel(cfg, max_seq=64)
        params = model.init(jax.random.key(0))
        B, S = 4, 32
        batch = {
            "tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
        if cfg.enc_dec:
            batch["enc_inputs"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
        ocfg = O.OptConfig(algorithm=cfg.optimizer,
                           state_dtype=cfg.opt_state_dtype)
        state = O.init_state(ocfg, params)
        step = jax.jit(make_train_step(model, ocfg))
        params, state, _ = step(params, state, batch)  # warm
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            params, state, m = step(params, state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"lm_step/{arch}/train_us", dt * 1e6,
                     f"{B * S / dt:.0f}tok/s"))

        # decode
        _, serve_step = make_serve_fns(model)
        cache = model.init_cache(B, 64)
        toks = jnp.ones((B, 1), jnp.int32)
        nxt, _, cache = serve_step(params, toks, cache)  # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            nxt, _, cache = serve_step(params, nxt, cache)
        jax.block_until_ready(nxt)
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"lm_step/{arch}/decode_us", dt * 1e6,
                     f"{B / dt:.0f}tok/s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
