"""Out-of-core scale sweep: partitioned BFS/SSSP at 500k / 5M / 20M edges.

Each scale point is an R-MAT partition container (built reproducibly by
:func:`repro.data.graphs.build_partition_container` into an uncommitted
cache dir — seed-deterministic, so every machine regenerates identical
containers) run through the streamed engine under a partition budget
*smaller than the graph's total edge-array bytes*, so the store must
evict and the stream must actually move data.  Per scale the payload
records MTEPS, wall time, bytes transferred, partitions skipped, the
measured transfer/compute overlap efficiency, and a peak host/device
memory snapshot; the smallest scale additionally cross-checks the
partitioned answer bit-exact against the resident path (the only scale
where both modes comfortably fit).
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import memory_snapshot

# (num_vertices, num_edges) per scale point — V = E/10, R-MAT at the
# paper-graph density.  20M edges is the 10M+ acceptance scale.
SCALES = ((50_000, 500_000), (500_000, 5_000_000), (2_000_000, 20_000_000))
CACHE_DIR = os.path.join("reports", "graphs", "scale_cache")
PARTITIONS = 4


def _label(num_edges: int) -> str:
    if num_edges >= 1_000_000:
        return f"{num_edges // 1_000_000}M"
    return f"{num_edges // 1_000}k"


def _container(cache_dir: str, v: int, e: int):
    from repro.data import graphs as D
    path = os.path.join(cache_dir, f"rmat_v{v}_e{e}_p{PARTITIONS}.npz")
    t0 = time.perf_counter()
    if not os.path.exists(path):
        D.build_partition_container(path, v, e, partitions=PARTITIONS,
                                    seed=0)
    build_s = time.perf_counter() - t0
    return D.load_partition_container(path), build_s


def _run_one(program, container, budget: int, root: int) -> dict:
    from repro.core.comm import CommManager
    from repro.core.scheduler import ScheduleConfig
    from repro.core.translator import translate
    comm = CommManager()
    prog = translate(program, container,
                     ScheduleConfig(partition_budget_bytes=budget), comm)
    t0 = time.perf_counter()
    _, iters = prog.run(roots=root)
    wall_s = time.perf_counter() - t0
    st = prog.last_run_stats
    return {
        "wall_s": wall_s,
        "supersteps": int(iters),
        "mteps": st["edges_traversed"] / wall_s / 1e6 if wall_s > 0 else 0.0,
        "edges_traversed": st["edges_traversed"],
        "partitions": st["partitions"],
        "partitions_swept": st["partitions_swept"],
        "partitions_skipped": st["partitions_skipped"],
        "partition_bytes_h2d": st["partition_bytes_h2d"],
        "partition_transfer_s": st["partition_transfer_s"],
        "partition_compute_s": st["partition_compute_s"],
        "overlap_efficiency": st["overlap_efficiency"],
        "terminated": st["terminated"],
        "partition_retries": st["partition_retries"],
        "partition_corruptions": st["partition_corruptions"],
        "store": {k: st["partition_store"][k]
                  for k in ("resident_bytes", "max_bytes", "hits", "misses",
                            "evictions", "builds", "build_s")},
    }


def _checkpoint_overhead(container, budget: int, root: int,
                         plain_wall_s: float) -> dict:
    """Checkpointed BFS at the default cadence vs the plain run.

    Runs the same streamed BFS with ``checkpoint_dir=`` (tempdir,
    default ``DEFAULT_STREAM_SWEEPS`` cadence) and reports the measured
    wall-clock ratio — the acceptance figure is < 10% overhead at the
    5M-edge point.  Bit-exactness is asserted, not assumed.
    """
    import shutil
    import tempfile
    from repro.core import dsl
    from repro.core.comm import CommManager
    from repro.core.scheduler import ScheduleConfig
    from repro.core.translator import translate
    ckdir = tempfile.mkdtemp(prefix="repro-ckpt-")
    try:
        prog = translate(dsl.bfs_program(), container,
                         ScheduleConfig(partition_budget_bytes=budget),
                         CommManager(), checkpoint_dir=ckdir)
        t0 = time.perf_counter()
        _, iters = prog.run(roots=root)
        wall_s = time.perf_counter() - t0
        st = prog.last_run_stats
        return {
            "wall_s": wall_s,
            "plain_wall_s": plain_wall_s,
            "overhead_ratio": (wall_s / plain_wall_s - 1.0
                               if plain_wall_s > 0 else 0.0),
            "checkpoint_saves": st["checkpoint_saves"],
            "checkpoint_write_s": st["checkpoint_write_s"],
            "supersteps": int(iters),
        }
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


def collect_scale_sweep(scales=SCALES, cache_dir: str = CACHE_DIR) -> dict:
    """The ≥3-point scale payload merged under ``scale_sweep``."""
    from repro.core import dsl
    from repro.core.scheduler import ScheduleConfig, estimate_stream_bytes
    from repro.core.translator import translate
    os.makedirs(cache_dir, exist_ok=True)
    min_edges = min(e for _, e in scales)
    out: dict = {"partitions": PARTITIONS, "scales": {}}
    for v, e in scales:
        container, build_s = _container(cache_dir, v, e)
        # the out-of-core constraint under test: the streamed-layout
        # budget is a third of the edge stream, far below the total
        # edge-array bytes, so layouts evict and every superstep moves
        # only what the frontier keeps live
        budget = estimate_stream_bytes(e) // 3
        root = int(np.argmax(container.out_degrees))
        entry: dict = {
            "num_vertices": v,
            "num_edges": e,
            "container_build_s": build_s,
            "partition_budget_bytes": budget,
            "edge_stream_bytes": estimate_stream_bytes(e),
            "bfs": _run_one(dsl.bfs_program(), container, budget, root),
        }
        if e == max(ee for _, ee in scales):
            # acceptance scale: SSSP end-to-end as well
            entry["sssp"] = _run_one(dsl.sssp_program(), container, budget,
                                     root)
        if e == 5_000_000:
            # durable-checkpoint overhead at the default cadence — the
            # robustness acceptance point (< 10% wall at 5M edges)
            entry["checkpoint"] = _checkpoint_overhead(
                container, budget, root, entry["bfs"]["wall_s"])
        if e == min_edges:
            # the only scale where resident + partitioned both fit:
            # pin the streamed answer bit-exact against the oracle
            g = container.to_graph()
            ref, _ = translate(dsl.bfs_program(), g, ScheduleConfig()).run(
                roots=root)
            pp = translate(dsl.bfs_program(), container,
                           ScheduleConfig(partition_budget_bytes=budget))
            got, _ = pp.run(roots=root)
            entry["resident_crosscheck_bitexact"] = bool(
                np.array_equal(np.asarray(ref), np.asarray(got)))
        entry["memory"] = memory_snapshot()
        out["scales"][_label(e)] = entry
    return out


def run():
    """CSV rows for the default benchmark driver."""
    data = collect_scale_sweep()
    for label, s in data["scales"].items():
        b = s["bfs"]
        yield (f"scale_bfs_{label}", f"{b['wall_s'] * 1e6:.0f}",
               f"{b['mteps']:.1f}MTEPS/skip{b['partitions_skipped']}")
