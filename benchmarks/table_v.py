"""Paper Table V: generated-code efficiency and BFS throughput.

Columns reproduced per workload graph (R-MAT stand-ins at the exact |V|/|E|
of the paper's SNAP datasets — offline environment, DESIGN.md §6):

  * code lines — length of the *user program* (the DSL BFS definition),
    paper: FAgraph 35 vs Vivado-HLS 54 vs Spatial 128;
  * TT — translation time (stage+AOT-compile), paper: "tens of seconds";
  * RT — end-to-end running time (translate + preprocess + execute);
  * TP — MTEPS over traversed edges.

A "general-purpose translator" strawman is measured alongside: the same
superstep math but re-traced and re-jitted per iteration with no module
matching (what a generic per-kernel HLS flow does), so the translation-cost
and code-efficiency deltas the paper reports are visible on one machine.
Absolute MTEPS is not comparable to an Alveo U200 (hardware differs);
relative claims are (see DESIGN.md §6).
"""
from __future__ import annotations

import inspect
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import dsl
from repro.core import graph as G
from repro.core import operators as ops
from repro.core.preprocess import PAPER_GRAPHS, load_paper_graph
from repro.core.scheduler import ScheduleConfig
from repro.core.translator import translate

INT_MAX = alg.INT_MAX


def _dsl_code_lines() -> int:
    """Lines of the user-facing BFS program (DSL definition + driver call)."""
    src = inspect.getsource(dsl.bfs_program)
    driver = "levels, iters, report = alg.bfs(g, root=0)"
    return len([l for l in src.splitlines() if l.strip()]) + 1


def _naive_general_purpose_bfs(g: G.Graph, root: int):
    """Strawman: per-iteration retrace/re-jit, no module library."""
    seg_dst, src, _ = G.coo_arrays(G.reverse(g))
    V = g.num_vertices
    levels = np.full(V, INT_MAX, np.int64)
    levels[root] = 0
    active = np.zeros(V, bool)
    active[root] = True
    iters = 0
    while active.any():
        # a general-purpose flow rebuilds the kernel each time (fresh jit
        # with static iteration constant baked in → always retraces)
        @jax.jit
        def step(levels, active, it=iters):
            msg = jnp.where(active[src], levels[src] + 1, INT_MAX)
            red = jax.ops.segment_min(msg, seg_dst, V)
            new = jnp.minimum(levels, red)
            return new, new != levels

        lv, ac = step(jnp.asarray(levels), jnp.asarray(active))
        levels, active = np.asarray(lv), np.asarray(ac)
        iters += 1
    return levels, iters


def run() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    lines = _dsl_code_lines()
    rows.append(("table_v/code_lines_ours", 0.0, str(lines)))
    rows.append(("table_v/code_lines_paper_fagraph", 0.0, "35"))
    rows.append(("table_v/code_lines_paper_vivado", 0.0, "54"))
    rows.append(("table_v/code_lines_paper_spatial", 0.0, "128"))

    for name in PAPER_GRAPHS:
        t_pre0 = time.perf_counter()
        g = load_paper_graph(name, cache_dir="reports/graphs")
        t_pre = time.perf_counter() - t_pre0

        # ---- light-weight translator path --------------------------------
        t0 = time.perf_counter()
        prog = translate(dsl.bfs_program(INT_MAX), g,
                         ScheduleConfig(pipelines=8, backend="sparse"))
        tt = time.perf_counter() - t0
        # warm run then timed runs
        levels, iters = prog.run(roots=0)
        jax.block_until_ready(levels)
        t1 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            levels, iters = prog.run(roots=0)
            jax.block_until_ready(levels)
        exec_s = (time.perf_counter() - t1) / reps
        te = alg.traversed_edges(g, np.asarray(levels))
        mteps = te / exec_s / 1e6
        rt = tt + t_pre + exec_s
        tag = name.replace("-", "_")
        rows.append((f"table_v/{tag}/TT_s", tt * 1e6, f"{tt:.2f}"))
        rows.append((f"table_v/{tag}/RT_s", rt * 1e6, f"{rt:.2f}"))
        rows.append((f"table_v/{tag}/exec_s", exec_s * 1e6,
                     f"{exec_s * 1e3:.1f}ms"))
        rows.append((f"table_v/{tag}/MTEPS", exec_s * 1e6, f"{mteps:.1f}"))
        rows.append((f"table_v/{tag}/traversed_edges", 0.0, str(te)))

        # ---- general-purpose strawman ------------------------------------
        t2 = time.perf_counter()
        lv2, _ = _naive_general_purpose_bfs(g, 0)
        naive_s = time.perf_counter() - t2
        np.testing.assert_array_equal(
            np.minimum(np.asarray(levels), INT_MAX),
            np.minimum(lv2, INT_MAX))
        mteps2 = te / naive_s / 1e6
        rows.append((f"table_v/{tag}/naive_RT_s", naive_s * 1e6,
                     f"{naive_s:.2f}"))
        rows.append((f"table_v/{tag}/naive_MTEPS", naive_s * 1e6,
                     f"{mteps2:.1f}"))
        rows.append((f"table_v/{tag}/speedup_vs_general", 0.0,
                     f"{naive_s / exec_s:.1f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
